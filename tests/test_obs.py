"""repro.obs: registry semantics, JSONL + CLI, and the instrumentation
threaded through lowering / fusion / codegen / solver driver — plus the
`Executable.profile` drift report for both program kinds.

Tests that need recording ON use `obs.capture()` so nothing leaks into
the process registry other tests (and the disabled-by-default gate in
test_perf_paths) rely on.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro import blas, obs
from repro.obs.__main__ import main as obs_cli
from repro.solvers import specs

# uniquely named copies of the canonical anchored chain: a cached
# compile skips the pipeline entirely (and so emits no spans/events),
# so instrumentation tests must force a fresh lowering
def _gemv_chain(name):
    return {
        "name": name,
        "routines": [
            {"blas": "gemv", "name": "mv",
             "scalars": {"alpha": 1.0, "beta": 0.0},
             "inputs": {"A": "A", "x": "p", "y": "y0"},
             "connections": {"out": "up.x"}, "outputs": {"out": "q"}},
            {"blas": "axpy", "name": "up",
             "scalars": {"alpha": {"input": "neg_alpha"}},
             "inputs": {"y": "r"},
             "connections": {"out": "rn.x"},
             "outputs": {"out": "r_next"}},
            {"blas": "nrm2", "name": "rn", "outputs": {"out": "rnorm"}},
        ],
    }


def _cg_ops(n=16):
    return {"A": jnp.eye(n, dtype=jnp.float32) * 2.0,
            "b": jnp.ones(n, jnp.float32),
            "x0": jnp.zeros(n, jnp.float32)}


# ---------------------------------------------------------------------------
# Registry core
# ---------------------------------------------------------------------------


def test_disabled_by_default_records_nothing():
    assert not obs.enabled()
    assert obs.span("x") is obs.NULL_SPAN
    obs.counter("c")
    obs.event("e")
    assert obs.records() == []
    assert obs.counters() == {}


def test_span_counter_event_record_shapes():
    with obs.capture() as reg:
        with obs.span("outer", program="p"):
            with obs.span("inner"):
                pass
            obs.counter("hits", 2, mode="dataflow")
            obs.event("decided", reason="because")
        recs = list(reg.records)
    inner, ctr, evt, outer = recs       # spans record on exit
    assert inner["kind"] == "span" and inner["name"] == "inner"
    assert inner["path"] == "outer/inner"       # nesting is recorded
    assert inner["dur_s"] >= 0.0
    assert outer["name"] == "outer"
    assert outer["attrs"] == {"program": "p"}
    assert outer["dur_s"] >= inner["dur_s"]
    assert ctr == {"kind": "counter", "name": "hits", "n": 2,
                   "attrs": {"mode": "dataflow"}}
    assert evt["kind"] == "event" and evt["name"] == "decided"
    assert evt["attrs"] == {"reason": "because"}
    assert reg.counters == {"hits": 2}


def test_capture_is_scoped():
    with obs.capture() as inner_reg:
        obs.event("inside")
        assert obs.enabled()
        assert len(inner_reg.records) == 1
    assert not obs.enabled()        # outer (disabled) registry restored
    assert obs.records() == []      # nothing leaked


def test_enable_disable_reset():
    obs.enable()
    try:
        obs.event("a")
        obs.counter("c")
        assert len(obs.records()) == 2
        obs.reset()
        assert obs.records() == [] and obs.counters() == {}
    finally:
        obs.disable()
        obs.reset()


# ---------------------------------------------------------------------------
# JSONL export + CLI
# ---------------------------------------------------------------------------


def _write_jsonl(tmp_path):
    with obs.capture() as reg:
        with obs.span("work", stage="s"):
            obs.counter("widgets", 3)
        obs.event("done", ok=True)
        path = reg.export_jsonl(tmp_path / "trace.jsonl")
    return path


def test_jsonl_roundtrip_and_summary(tmp_path):
    path = _write_jsonl(tmp_path)
    recs = obs.load_jsonl(path)
    assert [r["kind"] for r in recs] == ["counter", "span", "event"]
    s = obs.summarize_records(recs)
    assert s["spans"]["work"]["count"] == 1
    assert s["counters"]["widgets"] == 3
    assert s["events"]["done"] == 1
    assert "work" in obs.format_summary(s)


def test_cli_summarize_trace_diff(tmp_path, capsys):
    path = str(_write_jsonl(tmp_path))
    assert obs_cli(["summarize", path]) == 0
    out = capsys.readouterr().out
    assert "work" in out and "widgets" in out
    assert obs_cli(["trace", path, "--kind", "span", "--limit", "5"]) == 0
    assert "[span] work" in capsys.readouterr().out
    assert obs_cli(["diff", path, path]) == 0
    assert "B/A" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Pipeline instrumentation: lowering spans, cache counters, fusion
# decisions, codegen group tags
# ---------------------------------------------------------------------------


def test_lowering_spans_and_cache_counters():
    spec = _gemv_chain("obs_probe_lowering")
    with obs.capture() as reg:
        blas.compile(spec)                       # miss: full pipeline
        blas.compile(spec)                       # hit: cached IR
        recs = list(reg.records)
        ctrs = dict(reg.counters)
    span_names = {r["name"] for r in recs if r["kind"] == "span"}
    assert {"lowering.parse", "lowering.graph", "lowering.infer",
            "lowering.fuse", "lowering.place",
            "lowering.emit"} <= span_names
    assert ctrs.get("lowering.cache.miss", 0) == 1
    assert ctrs.get("lowering.cache.hit", 0) == 1
    done = [r for r in recs if r["kind"] == "event"
            and r["name"] == "lowering.done"]
    assert len(done) == 1                        # once per fresh lower
    assert done[0]["attrs"]["program"] == "obs_probe_lowering"


def test_fusion_decision_events():
    """The anchored chain absorbs its level-1 consumers: the planner's
    reasoning surfaces as one decision event per anchor candidate."""
    with obs.capture() as reg:
        blas.compile(_gemv_chain("obs_probe_fusion"))
        evts = [r for r in reg.records if r["kind"] == "event"
                and r["name"] in ("fusion.absorb", "fusion.reject")]
    absorbs = [e for e in evts if e["name"] == "fusion.absorb"]
    assert absorbs, "gemv anchor must absorb its axpy/nrm2 consumers"
    for e in evts:
        a = e["attrs"]
        assert a["program"] == "obs_probe_fusion"
        assert a["anchor"] == "mv"
        assert a["direction"] in ("down", "up")
        if e["name"] == "fusion.reject":
            assert a["reason"]


def test_codegen_group_events_tag_every_group():
    with obs.capture() as reg:
        exe = blas.compile(_gemv_chain("obs_probe_codegen"))
        evts = [r for r in reg.records if r["kind"] == "event"
                and r["name"] == "codegen.group"]
    assert len(evts) == len(exe._impl.ir.groups)
    kinds = {e["attrs"]["kind"] for e in evts}
    assert "anchored" in kinds                  # the gemv group
    anchored = [e for e in evts if e["attrs"]["kind"] == "anchored"]
    assert anchored[0]["attrs"]["anchor"] == "mv"
    assert "mv" in anchored[0]["attrs"]["routines"]


# ---------------------------------------------------------------------------
# Solver telemetry (satellite: history + per-solve export)
# ---------------------------------------------------------------------------


def test_solver_result_event_and_history_trimmed():
    exe = blas.compile(specs.CG_LOOP, max_iters=8)
    ops = _cg_ops()
    with obs.capture() as reg:
        res = exe.run(**ops)
        evts = [r for r in reg.records if r["kind"] == "event"
                and r["name"] == "solver.result"]
    assert len(evts) == 1
    a = evts[0]["attrs"]
    assert a["program"] == "cg"
    assert a["iterations"] == int(res.iterations)
    assert a["converged"] == bool(res.converged)
    assert a["final_residual"] == pytest.approx(float(res.residual))
    # history_trimmed drops the NaN tail past the stopping point
    trimmed = res.history_trimmed()
    assert len(trimmed) == int(res.iterations) + 1
    assert not jnp.isnan(jnp.asarray(trimmed)).any()
    assert jnp.isnan(res.history).sum() == len(res.history) - len(trimmed)


def test_solver_result_event_batched():
    exe = blas.compile(specs.CG_LOOP, max_iters=8)
    n, nrhs = 16, 3
    A = jnp.eye(n, dtype=jnp.float32) * 2.0
    B = jnp.stack([jnp.ones(n), 2.0 * jnp.ones(n),
                   3.0 * jnp.ones(n)]).astype(jnp.float32)
    with obs.capture() as reg:
        res = exe.batched(A=A, b=B, x0=jnp.zeros_like(B),
                          axes={"A": None})
        evts = [r for r in reg.records if r["kind"] == "event"
                and r["name"] == "solver.result"]
    assert len(evts) == 1
    a = evts[0]["attrs"]
    assert a["batch"] == nrhs
    assert a["iterations"] == [int(k) for k in res.iterations]
    assert a["converged"] == [bool(c) for c in res.converged]
    trimmed = res.history_trimmed()
    assert len(trimmed) == nrhs
    for lane, k in enumerate(res.iterations):
        assert len(trimmed[lane]) == int(k) + 1


# ---------------------------------------------------------------------------
# profile(): the modeled-vs-measured drift report (acceptance criteria)
# ---------------------------------------------------------------------------


def test_profile_dataflow_axpydot():
    import repro.core as core
    exe = blas.compile(core.AXPYDOT_SPEC)
    n = 64
    rep = exe.profile({"v": n, "w": n, "u": n}, iters=2)
    assert rep.kind == "dataflow" and rep.iters == 2
    assert len(rep.rows) == len(exe._impl.ir.groups)
    row = rep.rows[0]
    assert set(row.routines) == {"zcalc", "zdot"}   # fused group
    assert row.modeled_bytes > 0
    assert row.modeled_time_s > 0
    assert row.measured_s is not None and row.measured_s > 0
    assert row.drift == pytest.approx(
        row.measured_s / row.modeled_time_s)
    # modeled bytes apply the fusion savings in dataflow mode
    cr = exe.cost_report({"v": n, "w": n, "u": n})
    assert rep.modeled_bytes == cr.bytes
    j = rep.to_json()
    assert j["drift"] == rep.drift
    assert j["groups"][0]["routines"] == list(row.routines)
    json.dumps(j)                                # JSON-serializable


def test_profile_loop_cg():
    exe = blas.compile(specs.CG_LOOP, max_iters=4)
    rep = exe.profile({"A": (16, 16), "b": 16, "x0": 16}, iters=2)
    assert rep.kind == "loop"
    programs = {r.program for r in rep.rows}
    assert "cg_matvec" in programs               # the gemv body stage
    assert all(r.measured_s is not None for r in rep.rows)
    assert all((r.drift or 0) > 0 for r in rep.rows)
    assert rep.modeled_bytes > 0
    assert str(rep)                              # table renders


def test_profile_runs_without_enabling_obs():
    exe = blas.compile(specs.CG_LOOP, max_iters=4)
    assert not obs.enabled()
    exe.profile({"A": (16, 16), "b": 16, "x0": 16}, iters=1)
    assert not obs.enabled()
    assert obs.records() == []                   # scoped, no leakage


def test_profile_rejects_bad_iters_and_class_solvers():
    from repro.solvers import BiCGStab
    exe = blas.compile(specs.CG_LOOP)
    with pytest.raises(ValueError):
        exe.profile({"A": (8, 8), "b": 8, "x0": 8}, iters=0)
    wrapped = blas.Executable.from_solver(BiCGStab())
    with pytest.raises(TypeError):
        wrapped.profile({"A": (8, 8), "b": 8})
