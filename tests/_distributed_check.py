"""Subprocess body for distributed BLAS tests (needs 8 host devices,
so it must set XLA_FLAGS before jax initializes — cannot run in the
main pytest process)."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import axpydot_program, distributed as D  # noqa: E402
from repro.kernels import ref  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    n = 4 * 2048
    w, v, u, x, y = (jax.random.normal(k, (n,)) for k in ks[:5])

    # paxpy
    got = D.paxpy(mesh, 1.5, x, y)
    np.testing.assert_allclose(got, 1.5 * x + y, rtol=1e-5, atol=1e-5)

    # pdot
    got = D.pdot(mesh, x, y)
    np.testing.assert_allclose(got, ref.dot(x, y), rtol=1e-4, atol=1e-2)

    # fused distributed axpydot
    got = D.paxpydot(mesh, 0.7, w, v, u)
    want = ref.axpydot(jnp.float32(0.7), w, v, u)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)

    # pgemv over a 2-D sharded matrix
    m_, n_ = 4 * 64, 2 * 96
    a = jax.random.normal(ks[5], (m_, n_))
    xv = jax.random.normal(ks[6], (n_,))
    yv = jax.random.normal(ks[7], (m_,))
    got = D.pgemv(mesh, 1.1, a, xv, 0.3, yv)
    want = ref.gemv(1.1, a, xv, 0.3, yv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)

    # pgemm both strategies
    k_ = 2 * 128
    a = jax.random.normal(ks[5], (4 * 32, k_))
    b = jax.random.normal(ks[6], (k_, 2 * 64))
    want = a @ b
    got = D.pgemm(mesh, a, b, strategy="row_col", block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    got = D.pgemm(mesh, a, b, strategy="contract", block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)

    # whole-program data parallelism (multi-AXI-port axpydot)
    prog = axpydot_program()
    run = D.distribute_program(prog, mesh, axis="data")
    out = run(neg_alpha=jnp.float32(-0.7), w=w, v=v, u=u)
    np.testing.assert_allclose(out["beta"], want_beta(w, v, u),
                               rtol=1e-4, atol=1e-2)

    # collectives actually appear in the lowered HLO (NoC analogue)
    lowered = jax.jit(lambda x, y: D.pdot(mesh, x, y)).lower(x, y)
    hlo = lowered.compile().as_text()
    assert "all-reduce" in hlo, "expected an all-reduce in pdot HLO"

    # shard_map TP-expert MoE vs the dense oracle
    from repro.models.moe import moe_ffn_reference, moe_ffn_tp_shard_map
    from repro.models.layers import init_dense
    d, e, de, b, s = 32, 3, 16, 4, 8     # e % model(2) != 0 -> TP path
    kk = jax.random.split(jax.random.PRNGKey(9), 5)
    pmoe = {"router": init_dense(kk[0], (d, e)),
            "we_gate": init_dense(kk[1], (e, d, de)),
            "we_up": init_dense(kk[2], (e, d, de)),
            "we_down": init_dense(kk[3], (e, de, d))}
    xm = jax.random.normal(kk[4], (b, s, d))
    with jax.set_mesh(mesh):
        got = moe_ffn_tp_shard_map(
            pmoe, xm, n_experts=e, top_k=2, capacity_factor=4.0,
            act="silu", mesh=mesh)
    want = moe_ffn_reference(pmoe, xm.reshape(b * s, d), n_experts=e,
                             top_k=2).reshape(b, s, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    # shard_map EP MoE (e % model == 0) vs the dense oracle,
    # with DeepSeek-style shared experts
    from repro.models.moe import moe_ffn_ep_shard_map
    e2, de2 = 4, 16
    kk2 = jax.random.split(jax.random.PRNGKey(11), 8)
    pmoe2 = {"router": init_dense(kk2[0], (d, e2)),
             "we_gate": init_dense(kk2[1], (e2, d, de2)),
             "we_up": init_dense(kk2[2], (e2, d, de2)),
             "we_down": init_dense(kk2[3], (e2, de2, d)),
             "ws_gate": init_dense(kk2[4], (d, de2)),
             "ws_up": init_dense(kk2[5], (d, de2)),
             "ws_down": init_dense(kk2[6], (de2, d))}
    xm2 = jax.random.normal(kk2[7], (b, s, d))
    with jax.set_mesh(mesh):
        got = moe_ffn_ep_shard_map(
            pmoe2, xm2, n_experts=e2, top_k=2, capacity_factor=4.0,
            act="silu", mesh=mesh)
    want = moe_ffn_reference(pmoe2, xm2.reshape(b * s, d),
                             n_experts=e2, top_k=2).reshape(b, s, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    print("DISTRIBUTED-OK")


def want_beta(w, v, u):
    return ref.axpydot(jnp.float32(0.7), w, v, u)


if __name__ == "__main__":
    main()
