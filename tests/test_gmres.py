"""Grammar-v2 acceptance: GMRES(m) and BiCGStab as pure JSON loop
specs — conditional stages, stacked Krylov state, and nested restarts
executing as one jitted `lax.while_loop` nest."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lowering
from repro.solvers import BiCGStab, LoopProgram, specs

MODES = ["dataflow", "nodataflow"]


def _spd(n, seed=0):
    k = jax.random.PRNGKey(seed)
    m = jax.random.normal(k, (n, n), jnp.float32)
    return m @ m.T / n + jnp.eye(n, dtype=jnp.float32)


def _nonsym(n, seed=3):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (n, n), jnp.float32) / jnp.sqrt(n) \
        + 3.0 * jnp.eye(n)


def _rhs(n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)


# ---------------------------------------------------------------------------
# BiCGStab: the cond stage vs the class-based parity oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_bicgstab_spec_matches_class_iterate_for_iterate(mode):
    n = 96
    A, b = _nonsym(n), _rhs(n)
    lp = LoopProgram(specs.BICGSTAB_LOOP, mode=mode, max_iters=300)
    got = lp.solve(A=A, b=b, x0=jnp.zeros(n), tol=1e-7)
    want = BiCGStab(mode=mode, max_iters=300).solve(A, b, tol=1e-7)
    assert int(got.iterations) == int(want.iterations)
    assert bool(got.converged)
    np.testing.assert_allclose(got.x, want.x, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got.history, want.history,
                               rtol=1e-4, atol=1e-6)
    assert lp.trace_count == 1


def test_bicgstab_spec_takes_the_early_exit_branch():
    """On A = I the first half-step is exact: the spec-level cond
    (`snorm <= threshold`) finishes with x += alpha p and the loop
    stops after one iteration — same as the class solver."""
    n = 48
    b = _rhs(n)
    lp = LoopProgram(specs.BICGSTAB_LOOP, max_iters=50)
    res = lp.solve(A=jnp.eye(n), b=b, x0=jnp.zeros(n), tol=1e-6)
    assert int(res.iterations) == 1
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, b, rtol=1e-5, atol=1e-5)


def test_bicgstab_spec_batched_matches_per_rhs():
    n, nrhs = 64, 2
    A = _nonsym(n)
    B = jnp.stack([_rhs(n, s) for s in (5, 6)])
    lp = LoopProgram(specs.BICGSTAB_LOOP, max_iters=200)
    batched = lp.batched(A=A, b=B, x0=jnp.zeros_like(B),
                         axes={"A": None}, tol=1e-6)
    assert batched.x.shape == (nrhs, n)
    for i in range(nrhs):
        single = lp.solve(A=A, b=B[i], x0=jnp.zeros(n), tol=1e-6)
        assert int(batched.iterations[i]) == int(single.iterations)
        np.testing.assert_allclose(batched.x[i], single.x,
                                   rtol=1e-5, atol=1e-6)


def test_blas_bicgstab_runs_the_spec_path():
    from repro import blas
    from repro.blas import solvers as bs
    n = 64
    A, b = _nonsym(n), _rhs(n)
    bs._EXECUTABLES.clear()
    res = blas.bicgstab(A, b, tol=1e-6, max_iters=200)
    assert bool(res.converged)
    keys = list(bs._EXECUTABLES)
    assert any(k[0] == "loop" and k[1] == "bicgstab" for k in keys)
    exe = bs._EXECUTABLES[keys[0]]
    assert exe.spec is not None          # JSON all the way down
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# GMRES(m): stacked state + nested restarts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_A", [_spd, _nonsym],
                         ids=["spd", "nonsymmetric"])
def test_gmres_matches_scipy(make_A):
    scipy_linalg = pytest.importorskip("scipy.sparse.linalg")
    n, m = 64, 8
    A, b = make_A(n), _rhs(n)
    lp = LoopProgram(specs.gmres_loop(m=m), max_iters=40)
    got = lp.solve(A=A, b=b, x0=jnp.zeros(n), tol=1e-6)
    assert bool(got.converged)
    assert lp.trace_count == 1
    relres = float(jnp.linalg.norm(b - A @ got.x)
                   / jnp.linalg.norm(b))
    assert relres <= 1e-5
    xs, info = scipy_linalg.gmres(np.asarray(A), np.asarray(b),
                                  rtol=1e-6, restart=m, maxiter=40)
    assert info == 0
    np.testing.assert_allclose(got.x, xs, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("mode", MODES)
def test_gmres_modes_agree(mode):
    n = 48
    A, b = _nonsym(n), _rhs(n)
    lp = LoopProgram(specs.gmres_loop(m=6), mode=mode, max_iters=40)
    res = lp.solve(A=A, b=b, x0=jnp.zeros(n), tol=1e-6)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-3, atol=1e-4)
    assert lp.trace_count == 1


def test_gmres_exact_in_one_restart_when_m_covers_the_spectrum():
    """With restart length >= the matrix dimension a single cycle is a
    full-rank Krylov solve (happy breakdown masks unused slots)."""
    n = 12
    A, b = _nonsym(n, seed=7), _rhs(n)
    lp = LoopProgram(specs.gmres_loop(m=n), max_iters=5)
    res = lp.solve(A=A, b=b, x0=jnp.zeros(n), tol=1e-5)
    assert int(res.iterations) == 1
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-3, atol=1e-4)


def test_gmres_identity_happy_breakdown():
    """A = I breaks down after one Arnoldi step (w' = 0); safe
    divides keep the remaining slots zero and the filled prefix
    solves the system exactly."""
    n = 24
    b = _rhs(n)
    lp = LoopProgram(specs.gmres_loop(m=6), max_iters=5)
    res = lp.solve(A=jnp.eye(n), b=b, x0=jnp.zeros(n), tol=1e-6)
    assert bool(res.converged)
    assert int(res.iterations) == 1
    np.testing.assert_allclose(res.x, b, rtol=1e-5, atol=1e-5)


def test_gmres_batched_matches_per_rhs():
    n, nrhs = 48, 2
    A = _nonsym(n)
    B = jnp.stack([_rhs(n, s) for s in (2, 9)])
    lp = LoopProgram(specs.gmres_loop(m=6), max_iters=40)
    batched = lp.batched(A=A, b=B, x0=jnp.zeros_like(B),
                         axes={"A": None}, tol=1e-6)
    assert batched.x.shape == (nrhs, n)
    for i in range(nrhs):
        single = lp.solve(A=A, b=B[i], x0=jnp.zeros(n), tol=1e-6)
        assert int(batched.iterations[i]) == int(single.iterations)
        np.testing.assert_allclose(batched.x[i], single.x,
                                   rtol=1e-5, atol=1e-6)


def test_blas_gmres_convenience_and_memoization():
    from repro import blas
    from repro.blas import solvers as bs
    n = 48
    A, b = _nonsym(n), _rhs(n)
    bs._EXECUTABLES.clear()
    res = blas.gmres(A, b, tol=1e-6, restart=6, max_restarts=40)
    assert bool(res.converged)
    size = len(bs._EXECUTABLES)
    blas.gmres(A, 2.0 * b, tol=1e-6, restart=6, max_restarts=40)
    assert len(bs._EXECUTABLES) == size          # same compiled loop
    blas.gmres(A, b, tol=1e-6, restart=4, max_restarts=40)
    assert len(bs._EXECUTABLES) == size + 1      # new restart depth
    with pytest.raises(ValueError, match="restart"):
        blas.gmres(A, b, restart=0)


def test_gmres_describe_reports_nested_structure():
    lp = LoopProgram(specs.gmres_loop(m=4))
    desc = lp.describe()
    assert "inner loop (counter j)" in desc
    assert "V[5]" in desc                       # stack + slot count
    assert "store" in desc and "read" in desc
    assert "count 4" in desc


def test_gmres_cost_report_charges_inner_loops_per_trip():
    from repro import blas
    exe = blas.compile(specs.gmres_loop(m=4))
    rep = exe.cost_report({"A": (128, 128), "b": 128, "x0": 128})
    # 4 Arnoldi steps x (A matvec + basis proj/correction) dominate:
    # well above one restart-level residual matvec
    assert rep.flops > 4 * 2 * 128 * 128
    assert any("x4" in label for label, *_ in rep.rows)


def test_gmres_loop_lowers_once_through_the_cache():
    spec = specs.gmres_loop(m=5)
    LoopProgram(spec)
    before = lowering.cache_stats()
    LoopProgram(spec)
    after = lowering.cache_stats()
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


# ---------------------------------------------------------------------------
# Inner-loop metric stop rule (count-free form)
# ---------------------------------------------------------------------------


def test_inner_loop_metric_stop_rule():
    """An inner iterate may stop on its own metric <= rtol * scale
    rule (with a static max_iters bound) instead of a fixed count."""
    spec = {
        "name": "halver",
        "operands": {"A": "matrix", "b": "vector", "x0": "vector"},
        "setup": [
            {"program": specs.NRM2, "inputs": {"x": "b"},
             "outputs": {"norm": "bnorm"}},
            {"program": specs.RESIDUAL, "inputs": {"x": "x0"},
             "outputs": {"r": "r0", "rnorm": "rnorm0"}},
        ],
        "iterate": {
            "state": {"x": {"init": "x0"}, "r": {"init": "r0"}},
            "body": [
                # halve a scalar until it drops below 0.1 * bnorm;
                # with rnorm0 = bnorm that takes 4 halvings
                {"iterate": {
                    "counter": "k",
                    "state": {"h": {"init": "rnorm0"}},
                    "body": [{"let": {"h2": "h * 0.5"}}],
                    "feedback": {"h": "h2"},
                    "while": {"metric": "h2", "init": "rnorm0",
                              "scale": "bnorm", "rtol": 0.1,
                              "max_iters": 64},
                    "yield": {"hfin": "h"},
                }},
                {"program": specs.RESIDUAL, "inputs": {"x": "x"},
                 "outputs": {"r": "r_next", "rnorm": "rn2"}},
                {"let": {"rnorm": "rn2 * 0 + hfin"}},
            ],
            "feedback": {"x": "x", "r": "r_next"},
            "while": {"metric": "rnorm", "init": "rnorm0",
                      "scale": "bnorm", "rtol": 1e-6, "max_iters": 1},
            "solution": {"x": "x"},
        },
    }
    n = 16
    b = jnp.ones(n)
    lp = LoopProgram(spec, max_iters=1)
    res = lp.solve(A=jnp.eye(n), b=b, x0=jnp.zeros(n), tol=1e-6)
    # h halves from ||b|| until <= 0.1 ||b||: 0.5^4 = 0.0625
    bnorm = float(jnp.linalg.norm(b))
    assert abs(float(res.residual) - 0.0625 * bnorm) < 1e-4
