"""MoE dispatch: sort-based capacity routing vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import init_dense
from repro.models.moe import moe_ffn, moe_ffn_reference, route_topk


def _params(key, d, e, de, shared=False):
    ks = iter(jax.random.split(key, 8))
    p = {"router": init_dense(next(ks), (d, e)),
         "we_gate": init_dense(next(ks), (e, d, de)),
         "we_up": init_dense(next(ks), (e, d, de)),
         "we_down": init_dense(next(ks), (e, de, d))}
    if shared:
        p["ws_gate"] = init_dense(next(ks), (d, de))
        p["ws_up"] = init_dense(next(ks), (d, de))
        p["ws_down"] = init_dense(next(ks), (de, d))
    return p


@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (8, 6)])
@pytest.mark.parametrize("shared", [False, True])
def test_moe_matches_dense_oracle_no_drops(e, k, shared):
    d, de, t = 32, 16, 64
    key = jax.random.PRNGKey(0)
    p = _params(key, d, e, de, shared)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    # capacity_factor large enough that nothing is dropped
    got = moe_ffn(p, x, n_experts=e, top_k=k, capacity_factor=float(e),
                  act="silu")
    want = moe_ffn_reference(p, x, n_experts=e, top_k=k, act="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0, output differs from oracle only on dropped tokens,
    and drops only reduce magnitude (dropped contribution is zero)."""
    d, de, e, k, t = 16, 8, 4, 2, 128
    p = _params(jax.random.PRNGKey(2), d, e, de)
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    tight = moe_ffn(p, x, n_experts=e, top_k=k, capacity_factor=1.0)
    loose = moe_ffn(p, x, n_experts=e, top_k=k, capacity_factor=8.0)
    # both finite; tight may drop some tokens but never NaN
    assert np.isfinite(np.asarray(tight)).all()
    assert np.isfinite(np.asarray(loose)).all()


def test_route_topk_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(4), (32, 8))
    gates, experts = route_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               rtol=1e-5)
    assert int(experts.max()) < 8


def test_moe_grads_flow():
    d, de, e, k, t = 16, 8, 4, 2, 32
    p = _params(jax.random.PRNGKey(5), d, e, de)
    x = jax.random.normal(jax.random.PRNGKey(6), (t, d))

    def loss(p):
        return jnp.sum(moe_ffn(p, x, n_experts=e, top_k=k,
                               capacity_factor=4.0) ** 2)

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
