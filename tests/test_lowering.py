"""Lowering pipeline: named passes over ProgramIR, partial lowering,
digest stability, and the (digest, mode, fuse, interpret) program
cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowering
from repro.core.runtime import AXPYDOT_SPEC, Program
from repro.kernels import ref

SPEC = AXPYDOT_SPEC


def test_full_pipeline_populates_ir():
    ir = lowering.lower(SPEC)
    assert ir.passes_run == ["parse", "graph", "infer", "fuse",
                             "place", "emit"]
    assert ir.spec.name == "axpydot"
    assert ir.graph.order == ["zcalc", "zdot"]
    assert ir.io.input_kinds == {"neg_alpha": "scalar", "v": "vector",
                                 "w": "vector", "u": "vector"}
    assert ir.io.output_kinds == {"beta": "scalar"}
    assert len(ir.groups) == 1 and ir.groups[0].fused
    assert callable(ir.fn)


def test_partial_lowering_upto():
    ir = lowering.lower(SPEC, upto="infer")
    assert ir.passes_run == ["parse", "graph", "infer"]
    assert ir.io is not None
    assert ir.groups is None and ir.fn is None


def test_emitted_fn_matches_reference():
    ir = lowering.lower(SPEC)
    n = 384
    w = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    out = ir.fn({"neg_alpha": -0.7, "w": w, "v": v, "u": u})
    want = ref.axpydot(jnp.float32(0.7), w, v, u)
    np.testing.assert_allclose(out["beta"], want, rtol=1e-4, atol=1e-3)


def test_digest_is_key_order_independent():
    a = {"name": "p", "routines": [{"blas": "axpy", "name": "a0"}]}
    b = {"routines": [{"name": "a0", "blas": "axpy"}], "name": "p"}
    assert lowering.spec_digest(a) == lowering.spec_digest(b)
    c = {"name": "q", "routines": [{"blas": "axpy", "name": "a0"}]}
    assert lowering.spec_digest(a) != lowering.spec_digest(c)


def test_cache_hits_same_key_misses_new_mode():
    before = lowering.cache_stats()
    ir1 = lowering.compile_cached(SPEC, mode="dataflow")
    ir2 = lowering.compile_cached(SPEC, mode="dataflow")
    assert ir1 is ir2
    mid = lowering.cache_stats()
    assert mid["hits"] >= before["hits"] + 1
    ir3 = lowering.compile_cached(SPEC, mode="nodataflow")
    assert ir3 is not ir1
    assert lowering.cache_stats()["misses"] >= mid["misses"]


def test_program_from_spec_shares_cached_ir():
    p1 = Program.from_spec(SPEC)
    p2 = Program.from_spec(SPEC)
    assert p1.ir is p2.ir
    # distinct Program wrappers still behave independently
    assert p1.describe() == p2.describe()


def test_place_pass_collects_hints():
    spec = {"routines": [
        {"blas": "axpy", "name": "a0",
         "inputs": {"x": "x", "y": "y"},
         "placement": {"x": ["data"], "y": ["data"]}}]}
    ir = lowering.lower(spec, upto="place")
    assert ir.placements == {"x": ("data",), "y": ("data",)}


def test_lower_loop_compiles_stage_programs_once():
    from repro.solvers import specs
    lowering.lower_loop(specs.JACOBI_LOOP)   # populate
    before = lowering.cache_stats()
    lir = lowering.lower_loop(specs.JACOBI_LOOP)
    after = lowering.cache_stats()
    assert after["misses"] == before["misses"]
    # RESIDUAL is shared by setup and body: same ProgramIR object
    setup_res = lir.setup[1].ir
    body_res = lir.body[1].ir
    assert setup_res is body_res


def test_loop_and_class_paths_share_cache_entries():
    """The float32 default must not perturb the digest: a body dict
    compiled inside a loop spec and directly via Program.from_spec is
    one cache entry."""
    from repro.solvers import specs
    lir = lowering.lower_loop(specs.JACOBI_LOOP)
    direct = Program.from_spec(specs.RESIDUAL)
    assert lir.body[1].ir is direct.ir
