"""Distributed BLAS ('multi-AIE') routines — run in a subprocess so the
8-device host platform doesn't leak into other tests' jax state."""
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = pathlib.Path(__file__).parent / "_distributed_check.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


@pytest.mark.slow
def test_distributed_blas_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(_SCRIPT)], env=env, capture_output=True,
        text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "DISTRIBUTED-OK" in proc.stdout
