"""The `repro.blas` front door: registry-generated routine functions,
the unified compile() -> Executable handle over both program kinds,
result ergonomics, persistence, the CLI, and the deprecation shims."""
import json
import pathlib
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import blas
from repro.core import routines as R, runtime
from repro.core.runtime import Results
from repro.kernels import ref
from repro.solvers import specs
from repro.solvers.driver import SolverResult

SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _spd(n, seed=0):
    k = jax.random.PRNGKey(seed)
    m = jax.random.normal(k, (n, n), jnp.float32)
    return m @ m.T / n + jnp.eye(n, dtype=jnp.float32)


def _rhs(n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,),
                             jnp.float32)


# ---------------------------------------------------------------------------
# Function layer
# ---------------------------------------------------------------------------


def test_every_registry_routine_is_a_blas_callable():
    for name in R.names():
        fn = getattr(blas, name)
        assert callable(fn), name
        assert name in blas.__all__
    assert blas.routines() == list(R.names())


def test_function_layer_matches_references():
    n = 384
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (n,), jnp.float32)
    y = jax.random.normal(k2, (n,), jnp.float32)
    np.testing.assert_allclose(blas.dot(x, y), ref.dot(x, y),
                               rtol=1e-4)
    np.testing.assert_allclose(blas.axpy(0.5, x, y),
                               ref.axpy(jnp.float32(0.5), x, y),
                               rtol=1e-5)
    np.testing.assert_allclose(blas.nrm2(x), ref.nrm2(x), rtol=1e-4)
    A = jax.random.normal(jax.random.PRNGKey(3), (64, 128), jnp.float32)
    xv = jax.random.normal(jax.random.PRNGKey(4), (128,), jnp.float32)
    yv = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    np.testing.assert_allclose(
        blas.gemv(1.5, 0.5, A, xv, yv),
        ref.gemv(jnp.float32(1.5), A, xv, jnp.float32(0.5), yv),
        rtol=1e-4, atol=1e-4)


def test_multi_output_routine_returns_port_ordered_tuple():
    x = jnp.arange(8.0)
    y = jnp.ones(8)
    out_x, out_y = blas.rot(0.6, 0.8, x, y)
    np.testing.assert_allclose(out_x, 0.6 * x + 0.8 * y, rtol=1e-6)
    np.testing.assert_allclose(out_y, 0.6 * y - 0.8 * x, rtol=1e-6)


def test_function_layer_compiles_once_per_configuration():
    from repro.core import lowering
    x = jnp.arange(16.0)
    y = jnp.ones(16)
    blas.asum(x)                      # warm the memos
    blas.axpy(2.0, x, y)
    before = lowering.cache_stats()
    for _ in range(5):
        blas.asum(x)
        blas.axpy(2.0, x, y)
    after = lowering.cache_stats()
    # repeated calls never consult the digest cache, let alone miss it
    assert after["misses"] == before["misses"]
    assert after["hits"] == before["hits"]


def test_function_layer_keyword_args_and_modes():
    x = jnp.arange(32.0)
    y = jnp.ones(32)
    df = blas.waxpby(alpha=2.0, beta=3.0, x=x, y=y)
    nodf = blas.waxpby(2.0, 3.0, x, y, mode="nodataflow")
    ref_ = blas.waxpby(2.0, 3.0, x, y, mode="reference")
    np.testing.assert_allclose(df, nodf, rtol=1e-6)
    np.testing.assert_allclose(df, ref_, rtol=1e-6)


def test_signatures_are_registry_derived():
    import inspect
    sig = inspect.signature(blas.gemv)
    assert list(sig.parameters)[:5] == ["alpha", "beta", "A", "x", "y"]
    assert sig.parameters["mode"].kind is inspect.Parameter.KEYWORD_ONLY


# ---------------------------------------------------------------------------
# compile() -> Executable, both kinds
# ---------------------------------------------------------------------------


def test_compile_dataflow_spec_runs_and_unwraps():
    exe = blas.compile(runtime.AXPYDOT_SPEC)
    assert exe.kind == "dataflow"
    n = 256
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    w, v, u = (jax.random.normal(k, (n,), jnp.float32)
               for k in (k1, k2, k3))
    out = exe.run(neg_alpha=-0.7, v=v, w=w, u=u)
    assert isinstance(out, Results)
    np.testing.assert_allclose(out.one(), out["beta"])
    np.testing.assert_allclose(exe.one(neg_alpha=-0.7, v=v, w=w, u=u),
                               ref.axpydot(jnp.float32(0.7), w, v, u),
                               rtol=1e-4, atol=1e-3)
    assert "FUSED" in exe.describe()


def test_compile_loop_spec_runs_and_converges():
    n = 96
    A, b = _spd(n), _rhs(n)
    exe = blas.compile(specs.CG_LOOP, max_iters=300)
    assert exe.kind == "loop"
    res = exe.run(A=A, b=b, x0=jnp.zeros_like(b), tol=1e-6)
    assert isinstance(res, SolverResult)
    assert bool(res.converged)
    np.testing.assert_allclose(exe.one(A=A, b=b, x0=jnp.zeros_like(b)),
                               res.x, rtol=1e-5, atol=1e-6)
    assert exe.input_names == ["A", "b", "x0"]
    assert exe.output_names == ["x"]


def test_compile_accepts_json_string_and_shares_the_cache():
    exe1 = blas.compile(runtime.AXPY_SPEC)
    exe2 = blas.compile(json.dumps(runtime.AXPY_SPEC))
    assert exe1._impl.ir is exe2._impl.ir     # digest-keyed cache hit


def test_one_raises_on_multi_output_program():
    exe = blas.compile(specs.CG_MATVEC)
    n = 32
    A = _spd(n)
    p = _rhs(n)
    with pytest.raises(ValueError, match="single-output"):
        exe.run(A=A, p=p).one()


def test_results_one_on_plain_program_call():
    prog = runtime.Program.from_spec(specs.NRM2)
    out = prog(x=jnp.arange(64.0))
    assert isinstance(out, Results)
    np.testing.assert_allclose(out.one(), out["norm"])


def test_executable_batched_dataflow():
    exe = blas.compile(runtime.AXPY_SPEC)
    x = jnp.arange(24.0).reshape(4, 6)
    y = jnp.ones((4, 6))
    out = exe.batched(alpha=0.5, x=x, y=y, axes={"alpha": None})
    assert out["out"].shape == (4, 6)
    np.testing.assert_allclose(out["out"], 0.5 * x + y, rtol=1e-6)
    with pytest.raises(ValueError, match="unknown inputs"):
        exe.batched(alpha=0.5, x=x, y=y, nope=x)


def test_executable_batched_loop_multi_rhs():
    n, nrhs = 64, 3
    A = _spd(n)
    B = jax.random.normal(jax.random.PRNGKey(7), (nrhs, n), jnp.float32)
    exe = blas.compile(specs.CG_LOOP, max_iters=300)
    res = exe.batched(A=A, b=B, x0=jnp.zeros_like(B), tol=1e-6)
    assert res.x.shape == (nrhs, n)
    assert bool(jnp.all(res.converged))


def test_save_load_roundtrip(tmp_path):
    n = 64
    A, b = _spd(n), _rhs(n)
    exe = blas.compile(specs.CG_LOOP, max_iters=300)
    path = exe.save(tmp_path / "cg.json")
    exe2 = blas.load(path, max_iters=300)
    r1 = exe.run(A=A, b=b, x0=jnp.zeros_like(b))
    r2 = exe2.run(A=A, b=b, x0=jnp.zeros_like(b))
    assert int(r1.iterations) == int(r2.iterations)
    np.testing.assert_allclose(r1.x, r2.x, rtol=1e-6)
    # saved artifact is a plain spec: pre-existing entrypoints read it
    from repro.solvers import LoopProgram
    lp = LoopProgram(json.loads(path.read_text()), max_iters=300)
    r3 = lp.solve(A=A, b=b, x0=jnp.zeros_like(b))
    assert int(r3.iterations) == int(r1.iterations)


def test_save_preserves_let_binding_order(tmp_path):
    exe = blas.compile(specs.CG_LOOP, max_iters=5)
    raw = json.loads(exe.save(tmp_path / "cg.json").read_text())
    lets = [s["let"] for s in raw["iterate"]["body"] if "let" in s]
    assert list(lets[0]) == ["alpha", "neg_alpha"]
    assert list(lets[1]) == ["rz_next", "beta"]


def test_cost_report_dataflow_counts_fusion_savings():
    exe = blas.compile(runtime.AXPYDOT_SPEC)
    n = 4096
    rep = exe.cost_report({"v": n, "w": n, "u": n})
    # axpy: 2n flops, dot: 2n flops
    assert rep.flops == 4 * n
    # the fused on-chip edge saves one write + one read of z
    assert rep.fused_savings == 2 * n * 4
    assert rep.bytes == rep.bytes_naive - rep.fused_savings
    assert rep.bound in ("compute", "memory")
    assert "kept on-chip by fusion" in str(rep)


def test_cost_report_loop_per_iteration():
    exe = blas.compile(specs.CG_LOOP, max_iters=5)
    n = 1024
    rep = exe.cost_report({"A": (n, n), "b": n, "x0": n})
    # per-iteration flops are dominated by the gemv matvec (2 n^2)
    assert rep.flops > 2 * n * n
    assert any(label.startswith("body:") for label, *_ in rep.rows)
    assert any(label.startswith("setup:") for label, *_ in rep.rows)
    with pytest.raises(ValueError, match="missing shape"):
        exe.cost_report({"A": (n, n)})


def test_executable_spec_is_isolated_from_caller_mutation(tmp_path):
    spec = json.loads(json.dumps(runtime.AXPY_SPEC))
    exe = blas.compile(spec)
    spec["routines"][0]["scalars"]["alpha"] = {"value": 99.0}
    assert exe.spec["routines"][0]["scalars"]["alpha"] == \
        {"input": "alpha"}
    saved = json.loads(exe.save(tmp_path / "axpy.json").read_text())
    assert saved["routines"][0]["scalars"]["alpha"] == \
        {"input": "alpha"}


def test_executables_of_same_spec_share_one_jitted_program():
    exe1 = blas.compile(runtime.AXPY_SPEC)
    exe2 = blas.compile(runtime.AXPY_SPEC)
    x = jnp.arange(16.0)
    exe1.run(alpha=1.0, x=x, y=x)
    exe2.run(alpha=2.0, x=x, y=x)
    assert exe1._jit_run is exe2._jit_run


def test_compile_rejects_mismatched_knobs():
    with pytest.raises(ValueError, match="loop program"):
        blas.compile(runtime.AXPY_SPEC, max_iters=5)
    with pytest.raises(ValueError, match="fuse"):
        blas.compile(specs.CG_LOOP, fuse=True)


# ---------------------------------------------------------------------------
# Solver convenience functions on the unified path
# ---------------------------------------------------------------------------


def test_blas_cg_matches_class_solver():
    from repro.solvers import CG
    n = 128
    A, b = _spd(n), _rhs(n)
    got = blas.cg(A, b, tol=1e-7, max_iters=300)
    want = CG(max_iters=300).solve(A, b, tol=1e-7)
    assert int(got.iterations) == int(want.iterations)
    np.testing.assert_allclose(got.x, want.x, rtol=1e-5, atol=1e-6)


def test_blas_bicgstab_and_power_iteration():
    n = 96
    k = jax.random.PRNGKey(3)
    A = jax.random.normal(k, (n, n), jnp.float32) / jnp.sqrt(n) \
        + 3.0 * jnp.eye(n)
    b = _rhs(n)
    res = blas.bicgstab(A, b, tol=1e-7, max_iters=300)
    assert bool(res.converged)
    spd = _spd(n)
    eig = blas.power_iteration(spd, tol=1e-9, max_iters=2000)
    np.testing.assert_allclose(eig.aux["eigenvalue"],
                               jnp.linalg.eigvalsh(spd)[-1], rtol=1e-4)


def test_blas_jacobi_converges():
    n = 96
    A = _spd(n)
    A = A + 2.0 * jnp.diag(jnp.sum(jnp.abs(A), axis=1))
    b = _rhs(n)
    res = blas.jacobi(A, b, tol=1e-6, max_iters=500)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-4, atol=1e-5)


def test_solver_executables_are_memoized():
    n = 48
    A, b = _spd(n), _rhs(n)
    from repro.blas import solvers as bs
    bs._EXECUTABLES.clear()
    blas.cg(A, b, max_iters=200)
    size = len(bs._EXECUTABLES)
    blas.cg(A, b, max_iters=200)
    assert len(bs._EXECUTABLES) == size


# ---------------------------------------------------------------------------
# Back-compat: every pre-existing entrypoint still works
# ---------------------------------------------------------------------------


def test_old_entrypoints_still_work():
    n = 64
    A, b = _spd(n), _rhs(n)
    prog = runtime.Program.from_spec(runtime.AXPY_SPEC)
    out = prog(alpha=1.0, x=b, y=b)
    assert out["out"].shape == (n,)
    from repro.solvers import LoopProgram, cg
    res = cg(A, b, tol=1e-6, max_iters=300)
    assert bool(res.converged)
    lp = LoopProgram(specs.CG_LOOP, max_iters=300)
    res2 = lp.solve(A=A, b=b, x0=jnp.zeros_like(b))
    assert bool(res2.converged)


def test_from_spec_shims_are_gone():
    """The cg_from_spec/jacobi_from_spec deprecation shims completed
    their cycle: repro.blas.cg / repro.blas.jacobi are the spec
    path."""
    import repro.solvers as solvers
    assert not hasattr(solvers, "cg_from_spec")
    assert not hasattr(solvers, "jacobi_from_spec")
    n = 64
    A, b = _spd(n), _rhs(n)
    res = blas.cg(A, b, tol=1e-6, max_iters=300)
    assert bool(res.converged)
    Ad = A + 2.0 * jnp.diag(jnp.sum(jnp.abs(A), axis=1))
    res = blas.jacobi(Ad, b, tol=1e-6, max_iters=500)
    assert bool(res.converged)


def test_import_repro_exposes_blas_lazily():
    import os
    code = ("import repro, sys; "
            "assert 'repro.blas' not in sys.modules; "
            "repro.blas.dot; "
            "assert 'repro.blas' in sys.modules")
    subprocess.run([sys.executable, "-c", code], check=True,
                   env=dict(os.environ, PYTHONPATH=SRC))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_list_prints_registry_table():
    from repro.blas.__main__ import main
    assert main(["--list"]) == 0
    out = blas.api_table()
    for name in R.names():
        assert f"blas.{name}(" in out


def test_cli_spec_roundtrips_through_compile(capsys):
    from repro.blas.__main__ import main
    assert main(["--spec", "dot"]) == 0
    raw = json.loads(capsys.readouterr().out)
    exe = blas.compile(raw)
    x = jnp.arange(16.0)
    np.testing.assert_allclose(exe.one(x=x, y=x),
                               jnp.sum(x * x), rtol=1e-5)
    assert main(["--spec", "nosuch"]) == 2
