"""Solver subsystem: convergence vs jnp.linalg, dataflow/nodataflow
parity, early stopping, residual telemetry, and compile-once loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.solvers import (BiCGStab, CG, Jacobi, PowerIteration, cg,
                           jacobi)

MODES = ["dataflow", "nodataflow"]


def _spd(n, seed=0):
    k = jax.random.PRNGKey(seed)
    m = jax.random.normal(k, (n, n), jnp.float32)
    return m @ m.T / n + jnp.eye(n, dtype=jnp.float32)


def _diag_dominant(n, seed=0):
    a = _spd(n, seed)
    return a + 2.0 * jnp.diag(jnp.sum(jnp.abs(a), axis=1))


def _rhs(n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)


# ---------------------------------------------------------------------------
# Convergence vs jnp.linalg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 512, 4096])
def test_cg_solves_random_spd(n):
    A, b = _spd(n), _rhs(n)
    res = cg(A, b, tol=1e-6, max_iters=300)
    assert bool(res.converged)
    relres = float(jnp.linalg.norm(b - A @ res.x) / jnp.linalg.norm(b))
    assert relres <= 1e-5, relres
    x_ref = jnp.linalg.solve(A, b)
    np.testing.assert_allclose(res.x, x_ref, rtol=1e-4, atol=1e-4)


def test_bicgstab_solves_nonsymmetric():
    n = 256
    # diagonally-shifted nonsymmetric system
    k = jax.random.PRNGKey(3)
    A = jax.random.normal(k, (n, n), jnp.float32) / jnp.sqrt(n) \
        + 3.0 * jnp.eye(n)
    b = _rhs(n)
    res = BiCGStab(max_iters=300).solve(A, b, tol=1e-7)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-3, atol=1e-4)


def test_jacobi_converges_on_diag_dominant():
    n = 128
    A, b = _diag_dominant(n), _rhs(n)
    res = jacobi(A, b, tol=1e-6, max_iters=500)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b),
                               rtol=1e-4, atol=1e-5)
    # reported residual belongs to the returned iterate, not the
    # previous one
    np.testing.assert_allclose(res.residual,
                               jnp.linalg.norm(b - A @ res.x),
                               rtol=1e-3)


def test_power_iteration_finds_dominant_eigenpair():
    n = 128
    A = _spd(n)
    res = PowerIteration(max_iters=2000).solve(A, tol=1e-9)
    lam = res.aux["eigenvalue"]
    lam_true = jnp.linalg.eigvalsh(A)[-1]
    np.testing.assert_allclose(lam, lam_true, rtol=1e-4)
    # eigvector residual ‖A v − λ v‖ small
    v = res.x
    assert float(jnp.linalg.norm(A @ v - lam * v)) < 1e-2


# ---------------------------------------------------------------------------
# Mode parity: dataflow and nodataflow produce identical iterates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls,make_A", [
    (CG, _spd), (BiCGStab, _spd), (Jacobi, _diag_dominant)])
def test_linear_solver_mode_parity(cls, make_A):
    n = 200
    A, b = make_A(n), _rhs(n)
    results = {m: cls(mode=m, max_iters=100).solve(A, b, tol=1e-7)
               for m in MODES}
    assert (int(results["dataflow"].iterations)
            == int(results["nodataflow"].iterations))
    np.testing.assert_allclose(results["dataflow"].x,
                               results["nodataflow"].x,
                               rtol=1e-5, atol=1e-6)
    # residual histories track each other iteration by iteration
    np.testing.assert_allclose(results["dataflow"].history,
                               results["nodataflow"].history,
                               rtol=1e-3, atol=1e-5)


def test_power_iteration_mode_parity():
    A = _spd(100)
    results = {m: PowerIteration(mode=m, max_iters=500).solve(A, tol=1e-8)
               for m in MODES}
    np.testing.assert_allclose(results["dataflow"].aux["eigenvalue"],
                               results["nodataflow"].aux["eigenvalue"],
                               rtol=1e-5)
    # the dataflow matvec is now the anchored streaming kernel, not
    # the standalone gemv, so the trajectories are equal only up to
    # accumulated f32 rounding — both must land on the same eigenpair
    np.testing.assert_allclose(results["dataflow"].x,
                               results["nodataflow"].x,
                               rtol=1e-3, atol=1e-3)
    for m in MODES:
        r = results[m]
        lam = np.float64(r.aux["eigenvalue"])
        x = np.asarray(r.x, np.float64)
        resid = np.linalg.norm(np.asarray(A, np.float64) @ x - lam * x)
        assert resid <= 1e-3 * abs(lam), (m, resid)


# ---------------------------------------------------------------------------
# Stopping behaviour + telemetry
# ---------------------------------------------------------------------------


def test_early_stop_on_max_iters():
    A, b = _spd(128), _rhs(128)
    res = CG(max_iters=3).solve(A, b, tol=1e-12)
    assert int(res.iterations) == 3
    assert not bool(res.converged)


def test_stops_before_max_iters_on_tolerance():
    A, b = _spd(128), _rhs(128)
    res = CG(max_iters=200).solve(A, b, tol=1e-5)
    assert bool(res.converged)
    assert int(res.iterations) < 200


def test_zero_rhs_converges_instantly():
    A = _spd(64)
    res = CG(max_iters=50).solve(A, jnp.zeros(64), tol=1e-6)
    assert int(res.iterations) == 0
    assert bool(res.converged)
    np.testing.assert_array_equal(np.asarray(res.x), np.zeros(64))


def test_residual_history_telemetry():
    A, b = _spd(128), _rhs(128)
    res = CG(max_iters=100).solve(A, b, tol=1e-6)
    k = int(res.iterations)
    hist = np.asarray(res.history)
    assert hist.shape == (101,)
    assert np.all(np.isfinite(hist[:k + 1]))
    assert np.all(np.isnan(hist[k + 1:]))
    np.testing.assert_allclose(hist[0], jnp.linalg.norm(b), rtol=1e-5)
    np.testing.assert_allclose(hist[k], res.residual, rtol=1e-6)
    # CG residuals on a well-conditioned SPD system shrink overall
    assert hist[k] < 1e-3 * hist[0]


# ---------------------------------------------------------------------------
# Compile-once: the loop body is traced exactly once per shape
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls,make_A", [
    (CG, _spd), (BiCGStab, _spd), (Jacobi, _diag_dominant)])
def test_loop_body_compiles_once(cls, make_A):
    n = 96
    A, b = make_A(n), _rhs(n)
    solver = cls(max_iters=50)
    solver.solve(A, b, tol=1e-6)
    assert solver.trace_count == 1
    # same shapes, different values/tol: jit cache hit, no retrace
    solver.solve(A + 0.1 * jnp.eye(n), b * 2.0, tol=1e-4)
    assert solver.trace_count == 1
    # new shape: exactly one more trace
    solver.solve(make_A(2 * n), _rhs(2 * n), tol=1e-6)
    assert solver.trace_count == 2


def test_solver_describe_lists_fused_groups():
    solver = CG(mode="dataflow")
    desc = solver.describe()
    assert "FUSED on-chip group" in desc
    assert "cg_update" in desc
    nodesc = CG(mode="nodataflow").describe()
    assert "FUSED" not in nodesc
