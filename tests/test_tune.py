"""repro.tune: tile configs/plans, the persistent tuning table, the
autotuner sweep, and the tiles= threading through lowering and the
blas API.

Covers the cache-key correctness the tuning work leans on (two tile
configs of one digest yield two lowering-cache entries with accurate
hit/miss counters), the across-process persistence acceptance (second
process fires `tune.cache.hit` and performs zero sweeps), and the
profile-vs-bench drift regression (the per-call pallas rebuild that
once made `Executable.profile` report ~500x the benchmark wall clock).

Every store-touching test runs against a throwaway REPRO_CACHE_DIR so
a developer's real ~/.cache/repro is never read or written.
"""
import json
import time

import jax
import jax.numpy as jnp
import pytest

from repro import blas, obs
from repro.core import lowering
from repro.tune import autotuner
from repro.tune import config as C
from repro.tune import store as S
from repro.tune.__main__ import main as tune_cli

N = 48


@pytest.fixture
def fresh_store(monkeypatch, tmp_path):
    """Isolated on-disk table + cold lowering caches; restores the
    process-wide store (and caches) afterwards so other test files
    keep their digest-cache assumptions."""
    monkeypatch.setenv(S.ENV_CACHE_DIR, str(tmp_path))
    S.reset_store()
    lowering.clear_cache()
    yield S.get_store()
    monkeypatch.delenv(S.ENV_CACHE_DIR)
    S.reset_store()
    lowering.clear_cache()


def _chain(name):
    return {
        "name": name,
        "routines": [
            {"blas": "symv", "name": "mv",
             "scalars": {"alpha": 1.0, "beta": 0.0},
             "inputs": {"A": "A", "x": "x", "y": "x"},
             "connections": {"out": "d.x"}},
            {"blas": "dot", "name": "d", "inputs": {"y": "x"},
             "outputs": {"out": "q"}},
        ],
    }


def _chain_inputs(n, seed=0):
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(k, (n, n), jnp.float32)
    return {"A": (a + a.T) / 2,
            "x": jax.random.normal(jax.random.PRNGKey(1), (n,),
                                   jnp.float32)}


# ---------------------------------------------------------------------------
# TileConfig / buckets / TilePlan
# ---------------------------------------------------------------------------


def test_tile_config_key_and_json_roundtrip():
    cfg = C.TileConfig(block_m=256, block_n=512)
    assert cfg.key() == "m256.n512"
    assert C.TileConfig().key() == "default"
    assert C.TileConfig.from_json(cfg.to_json()) == cfg
    assert C.TileConfig.from_json({}) == C.TileConfig()


def test_tile_config_rejects_bad_values():
    with pytest.raises(ValueError):
        C.TileConfig(block_m=0)
    with pytest.raises(ValueError):
        C.TileConfig.from_json({"block_q": 128})


def test_shape_bucket_pow2():
    assert C.bucket_dim(1000) == 1024
    assert C.bucket_dim(1024) == 1024
    assert C.bucket_dim(1) == 1
    assert C.shape_bucket(1000, 2000) == "1024x2048"
    assert C.shape_bucket(48) == "64"
    assert C.shape_bucket() == "scalar"


def test_clamp_is_the_sweep_dedup_key():
    big = C.TileConfig(block_m=512, block_n=1024)
    small = C.TileConfig(block_m=128, block_n=128)
    # at a tiny problem every oversized candidate clamps to one shape
    assert C.clamp(big, (64, 64)) == C.clamp(
        C.TileConfig(block_m=1024, block_n=1024), (64, 64))
    assert C.clamp(small, (64, 64)) == C.TileConfig(block_m=64,
                                                    block_n=64)
    assert C.clamp(C.TileConfig(block_rows=512), (100,)) == \
        C.TileConfig(block_rows=100)


def test_tile_plan_wildcard_and_lookup():
    cfg = C.TileConfig(block_m=128, block_n=128)
    plan = C.TilePlan.everywhere(cfg)
    assert plan.get("g0", "256x256") == cfg
    assert plan.lookup("g7")(1000, 1000) == cfg
    sited = C.TilePlan.from_dict({"g0": {"256x256": cfg}})
    assert sited.get("g0", "256x256") == cfg
    assert sited.get("g0", "512x512") is None
    assert sited.get("g1", "256x256") is None
    # lookup buckets the concrete dims before matching
    assert sited.lookup("g0")(200, 200) == cfg


def test_tile_plan_key_is_content_addressed():
    cfg = C.TileConfig(block_m=128)
    a = C.TilePlan.from_dict({"g0": {"*": cfg}})
    b = C.TilePlan.from_dict({"g0": {"*": C.TileConfig(block_m=128)}})
    c = C.TilePlan.from_dict({"g0": {"*": C.TileConfig(block_m=256)}})
    assert a.key() == b.key() != c.key()
    assert C.EMPTY_PLAN.key() == "default"
    assert not C.EMPTY_PLAN and a


# ---------------------------------------------------------------------------
# The on-disk store
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_atomic_write(fresh_store, tmp_path):
    st = fresh_store
    cfg = C.TileConfig(block_m=256, block_n=256)
    st.record_entry("symv+dot", "256x256", "dataflow", True, True,
                    "cpu", tiles=cfg, us=10.0, default_us=15.0,
                    sweeps=3)
    st.put_artifact("a" * 64, "dataflow", True, True, "cpu",
                    spec={"name": "p"}, plan=C.TilePlan.everywhere(cfg),
                    tuned=True)
    # no tmp droppings, one well-formed table
    leftovers = [p for p in tmp_path.iterdir()
                 if p.suffix == ".tmp"]
    assert not leftovers
    reread = S.TuningTable(tmp_path / S.TABLE_FILENAME)
    assert reread.validate() == []
    assert reread.entries_for("symv+dot", "dataflow", True, True,
                              "cpu") == {"256x256": cfg}
    assert reread.artifact_plan("a" * 64, "dataflow", True, True,
                                "cpu").get("g0", "64x64") == cfg
    assert reread.artifact_spec("a" * 64, "dataflow", True, True,
                                "cpu") == {"name": "p"}


def test_store_tolerates_corrupt_and_foreign_files(tmp_path):
    path = tmp_path / S.TABLE_FILENAME
    path.write_text("{not json")
    assert S.TuningTable(path).doc["entries"] == {}
    path.write_text(json.dumps({"schema": "repro.tune/v999",
                                "version": 999, "entries": {"x": {}}}))
    st = S.TuningTable(path)            # unknown version: start empty
    assert st.doc["entries"] == {}
    # and a write does not resurrect the foreign content
    st.record_entry("gemv", "64x64", "dataflow", False, False, "cpu",
                    tiles=C.TileConfig(block_m=64), us=1.0,
                    default_us=1.0)
    on_disk = json.loads(path.read_text())
    assert on_disk["version"] == S.SCHEMA_VERSION
    assert "x" not in on_disk["entries"]


def test_put_artifact_merges_shape_buckets(fresh_store):
    """A tune at one shape bucket must not erase another bucket's
    persisted winner for the same digest."""
    st = fresh_store
    small = C.TileConfig(block_m=256, block_n=256)
    large = C.TileConfig(block_m=512, block_n=512)
    st.put_artifact("d" * 64, "dataflow", True, True, "cpu",
                    spec={"name": "p"},
                    plan=C.TilePlan.from_dict({"g0": {"256x256": small}}),
                    tuned=True)
    st.put_artifact("d" * 64, "dataflow", True, True, "cpu",
                    spec={"name": "p"},
                    plan=C.TilePlan.from_dict({"g0": {"1024x1024": large}}),
                    tuned=True)
    plan = st.artifact_plan("d" * 64, "dataflow", True, True, "cpu")
    assert plan.get("g0", "256x256") == small
    assert plan.get("g0", "1024x1024") == large


def test_validate_doc_flags_malformed_tables():
    bad = {"schema": S.SCHEMA, "version": S.SCHEMA_VERSION,
           "entries": {"too|few|parts": {"us": 1.0}},
           "artifacts": {}}
    problems = S.validate_doc(bad)
    assert any("malformed key" in p for p in problems)
    assert any("missing 'tiles'" in p for p in problems)
    assert S.validate_doc([]) != []
    ok = {"schema": S.SCHEMA, "version": S.SCHEMA_VERSION,
          "entries": {}, "artifacts": {}}
    assert S.validate_doc(ok) == []


# ---------------------------------------------------------------------------
# Cache-key correctness: tiles in the lowering cache
# ---------------------------------------------------------------------------


def test_two_tile_configs_two_cache_entries(fresh_store):
    spec = _chain("tune_cache_key_chain")
    before = lowering.cache_stats()
    a = lowering.compile_cached(spec, tiles=C.TileConfig(block_m=128,
                                                         block_n=128))
    b = lowering.compile_cached(spec, tiles=C.TileConfig(block_m=256,
                                                         block_n=256))
    assert a is not b                   # same digest, two entries
    mid = lowering.cache_stats()
    assert mid["misses"] == before["misses"] + 2
    # recompiling either config is a pure hit
    a2 = lowering.compile_cached(spec, tiles=C.TileConfig(block_m=128,
                                                          block_n=128))
    assert a2 is a
    after = lowering.cache_stats()
    assert after["hits"] == mid["hits"] + 1
    assert after["misses"] == mid["misses"]


def test_auto_on_cold_store_shares_the_default_entry(fresh_store):
    """A cold store resolves "auto" to the empty plan, whose cache key
    equals "default" — so auto/default compiles share one entry and a
    cold fleet pays one lowering, not two."""
    spec = _chain("tune_cold_auto_chain")
    a = lowering.compile_cached(spec, tiles="auto")
    before = lowering.cache_stats()
    b = lowering.compile_cached(spec, tiles="default")
    after = lowering.cache_stats()
    assert b is a
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


def test_tuned_store_splits_the_cache_entry(fresh_store):
    """Once the table holds a winner, "auto" resolves to a non-empty
    plan and compiles apart from "default" — with correct numerics."""
    spec = _chain("tune_split_chain")
    inputs = _chain_inputs(N)
    default_exe = blas.compile(spec, tiles="default")
    want = default_exe.run(**inputs)["q"]
    # seed a winning artifact directly (at N=48 a real sweep clamps
    # every candidate onto the default shape and finds no winner)
    cfg = C.TileConfig(block_m=32, block_n=32)
    fresh_store.put_artifact(
        lowering.spec_digest(spec), "dataflow", True, True,
        C.current_device_kind(), spec=spec,
        plan=C.TilePlan.from_dict({"g0": {C.shape_bucket(N, N): cfg}}),
        tuned=True)
    lowering.clear_cache()              # force fresh resolution
    auto_ir = lowering.compile_cached(spec, tiles="auto")
    assert auto_ir.tile_plan            # picked up the tuned plan
    default_ir = lowering.compile_cached(spec, tiles="default")
    assert auto_ir is not default_ir    # distinct cache entries
    got = blas.compile(spec, tiles="auto").run(**inputs)["q"]
    assert jnp.allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Autotuner end to end
# ---------------------------------------------------------------------------


def test_tune_program_persists_entries_and_artifact(fresh_store):
    spec = _chain("tune_e2e_chain")
    rep = autotuner.tune_program(spec, {"A": (N, N), "x": N},
                                 budget=3, iters=1, store=fresh_store)
    assert rep.sweeps <= 3
    assert rep.baseline_us > 0 and rep.tuned_us > 0
    assert rep.tuned_us <= rep.baseline_us          # never regresses
    assert fresh_store.validate() == []
    # the anchored group shows up as a pattern entry + tuned artifact
    entries = fresh_store.entries_for("symv+dot", "dataflow", True,
                                      True, C.current_device_kind())
    assert C.shape_bucket(N, N) in entries
    digest = lowering.spec_digest(spec)
    plan = fresh_store.artifact_plan(digest, "dataflow", True, True,
                                     C.current_device_kind())
    assert plan is not None


def test_executable_tune_returns_recompiled_handle(fresh_store):
    spec = _chain("tune_exe_chain")
    inputs = _chain_inputs(N)
    exe = blas.compile(spec)
    want = exe.run(**inputs)["q"]
    tuned = exe.tune({"A": (N, N), "x": N}, budget=2, iters=1)
    assert tuned is not exe
    assert tuned.tune_report is not None
    assert tuned.tune_report.sweeps <= 2
    got = tuned.run(**inputs)["q"]
    assert jnp.allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cross_process_artifact_hit_with_zero_sweeps(fresh_store):
    """The acceptance scenario: process 1 compiles (cold miss,
    persists the artifact); "process 2" (fresh store handle + cold
    lowering caches, same cache dir) compiles again — the artifact
    hit fires `tune.cache.hit` and no sweep measurement runs."""
    spec = _chain("tune_xproc_chain")
    with obs.capture() as reg1:
        blas.compile(spec)
    recs1 = list(reg1.records)
    assert any(r["name"] == "tune.cache.miss" for r in recs1)
    assert not any(r["name"] == "tune.measure" for r in recs1)

    # simulate the second process
    S.reset_store()
    lowering.clear_cache()
    with obs.capture() as reg2:
        blas.compile(spec)
    recs2 = list(reg2.records)
    hits = [r for r in recs2 if r["name"] == "tune.cache.hit"]
    assert hits, "second process must hit the persisted artifact"
    assert not any(r["name"] == "tune.cache.miss" for r in recs2)
    assert not any(r["name"] == "tune.measure" for r in recs2)


def test_cold_compile_enqueues_no_sweeps(fresh_store):
    with obs.capture() as reg:
        blas.compile(_chain("tune_cold_chain"), tiles="auto")
    assert not any(r["name"] == "tune.measure" for r in reg.records)


def test_tune_cli_smoke_validates_own_table(fresh_store, tmp_path,
                                            capsys):
    out = tmp_path / "table.json"
    rc = tune_cli(["--smoke", "--n", "64", "--routines", "gemv",
                   "--chains", "symv_dot", "--json", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert S.validate_doc(doc) == []
    assert doc["entries"]
    rc = tune_cli(["--validate", str(out)])
    assert rc == 0
    capsys.readouterr()


# ---------------------------------------------------------------------------
# Drift regression: profile vs bench wall clock
# ---------------------------------------------------------------------------


def test_profile_and_bench_agree_within_an_order_of_magnitude():
    """`Executable.profile` once rebuilt (and so re-traced) the fused
    pallas_call on every eager run, reporting ~500x the benchmark wall
    clock for the same kernel. With per-shape memoized calls the two
    must agree within an order of magnitude at a kernel-dominated
    size (eager per-op dispatch keeps profile the larger number)."""
    n = 384
    spec = _chain("drift_regression_chain")
    exe = blas.compile(spec)
    rep = exe.profile({"A": (n, n), "x": n}, iters=2)
    assert rep.measured_s > 0
    inputs = _chain_inputs(n)
    out = exe.run(**inputs)
    jax.block_until_ready(out["q"])
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        out = exe.run(**inputs)
        jax.block_until_ready(out["q"])
        best = min(best, time.perf_counter() - t0)
    ratio = rep.measured_s / best
    assert ratio < 10.0, (
        f"profile {1e6 * rep.measured_s:.0f}us vs bench "
        f"{1e6 * best:.0f}us: ratio {ratio:.1f} (profile is timing "
        f"compilation again?)")
