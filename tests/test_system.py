"""End-to-end behaviour of the paper's system: JSON spec in, correct
dataflow execution out, with fusion visibly changing the plan but
never the semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AXPYDOT_SPEC, Program
from repro.kernels import ref


def test_axpydot_end_to_end_all_modes():
    """The paper's flagship composition, through the full pipeline:
    parse -> graph -> fusion -> generated kernel -> execution."""
    n = 20_000
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(k1, (n,))
    v = jax.random.normal(k2, (n,))
    u = jax.random.normal(k3, (n,))
    want = ref.axpydot(jnp.float32(0.6), w, v, u)

    results = {}
    for mode in ("dataflow", "nodataflow", "reference"):
        prog = Program.from_spec(AXPYDOT_SPEC, mode=mode)
        results[mode] = prog(neg_alpha=-0.6, w=w, v=v, u=u)["beta"]
    for mode, got in results.items():
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2,
                                   err_msg=mode)

    # the fusion plan differs (1 fused group vs 2 kernels)...
    df = Program.from_spec(AXPYDOT_SPEC, mode="dataflow")
    ndf = Program.from_spec(AXPYDOT_SPEC, mode="nodataflow")
    assert len(df.groups) == 1 and df.groups[0].fused
    assert len(ndf.groups) == 2
    # ...and the user-facing description reflects the on-chip edge
    assert "FUSED" in df.describe()


def test_window_size_knob_changes_blocking_not_results():
    """The paper's non-functional window_size knob: different blocks,
    identical numerics."""
    import copy
    n = 4_096
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    w, v, u = (jax.random.normal(k, (n,)) for k in (k1, k2, k3))
    outs = []
    for ws in (128, 256, 512):
        spec = copy.deepcopy(AXPYDOT_SPEC)
        spec["window_size"] = ws
        prog = Program.from_spec(spec)
        outs.append(float(prog(neg_alpha=-0.3, w=w, v=v, u=u)["beta"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_spec_to_model_substrate_round_trip():
    """The model stack's dense() really is the BLAS substrate: a
    projection computed via the library gemm kernel matches the model
    path."""
    from repro.kernels import ops
    from repro.models.layers import dense, use_pallas
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (64, 128))
    wt = jax.random.normal(jax.random.fold_in(key, 1), (128, 96))
    want = dense(x, wt)                       # jnp reference path
    with use_pallas(True):
        got = dense(x, wt)                    # Pallas gemm kernel path
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
