"""Fault-tolerance machinery: atomic checkpointing, CRC verification,
restart/restore, elastic re-sharding, straggler + heartbeat monitors."""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import HeartbeatMonitor, StragglerWatchdog


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"params": {"w": jax.random.normal(k1, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jax.random.normal(k2, (8, 8)),
                          "b": jnp.ones((8,))}},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    assert mgr.latest_valid_step() == 10
    step, restored = mgr.restore_latest(tree)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b),
                 tree, restored)


def test_corrupted_checkpoint_skipped(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    # corrupt step 2: flip bytes in one array
    d = tmp_path / "step_0000000002"
    target = sorted(d.glob("arr_*.npy"))[0]
    raw = bytearray(target.read_bytes())
    raw[-8] ^= 0xFF
    target.write_bytes(bytes(raw))
    assert mgr.latest_valid_step() == 1  # falls back to last good
    step, restored = mgr.restore_latest(tree)
    assert step == 1


def test_torn_write_never_published(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    # simulate a crash mid-write: a .tmp dir left behind
    tmp = tmp_path / "step_0000000005.tmp"
    tmp.mkdir()
    (tmp / "arr_00000.npy").write_bytes(b"garbage")
    assert mgr.all_steps() == [1]
    assert mgr.latest_valid_step() == 1


def test_keep_last_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (single-device) shardings — the elastic
    re-mesh path."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(3, tree, blocking=True)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), tree)
    step, restored = mgr.restore_latest(tree, shardings=sh)
    assert step == 3
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding is not None


def test_missing_array_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.ones(3)}, blocking=True)
    with pytest.raises(ValueError, match="missing"):
        mgr.restore(1, {"a": jnp.ones(3), "b": jnp.ones(3)})


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, min_samples=5)
    for i in range(20):
        assert not wd.record(i, 1.0)
    assert wd.record(20, 3.5)          # 3.5x median
    assert not wd.record(21, 1.4)
    assert wd.slow_steps == [20]


def test_heartbeat_monitor_failure_fires_once():
    t = [0.0]
    failed = []
    mon = HeartbeatMonitor(hosts=["h0", "h1"], interval_s=1.0,
                           suspect_after=2, dead_after=5,
                           on_failure=failed.append,
                           clock=lambda: t[0])
    t[0] = 3.0
    mon.beat("h0")
    assert mon.status("h1") == "suspected"
    assert mon.poll() == []
    t[0] = 6.0
    mon.beat("h0")
    assert mon.poll() == ["h1"]
    assert mon.poll() == []            # fires exactly once
    assert failed == ["h1"]
    assert mon.alive_hosts == ["h0"]
    # elastic rejoin
    mon.beat("h1")
    assert mon.status("h1") == "alive"
