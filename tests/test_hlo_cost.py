"""Loop-aware HLO cost analyzer vs analytically-known graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_text


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    txt = _compile_text(lambda a, b: a @ b, a, b)
    c = analyze_text(txt)
    assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_by_trip_count():
    w = jnp.zeros((7, 32, 32))
    x = jnp.zeros((8, 32))

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = analyze_text(_compile_text(f, w, x))
    want = 7 * 2 * 8 * 32 * 32
    assert c.flops == pytest.approx(want, rel=0.05)


def test_nested_scan_trip_products():
    w = jnp.zeros((3, 16, 16))

    def f(w, x):
        def outer(h, _):
            def inner(h2, wl):
                return jnp.tanh(h2 @ wl), None
            h, _ = jax.lax.scan(inner, h, w)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jnp.zeros((4, 16))
    c = analyze_text(_compile_text(f, w, x))
    want = 5 * 3 * 2 * 4 * 16 * 16
    assert c.flops == pytest.approx(want, rel=0.05)


def test_scan_weight_reads_counted_slicewise():
    """A scan reading one (128,128) layer per step must count ~L x
    layer bytes, not L x the full stacked array."""
    L = 10
    w = jnp.zeros((L, 128, 128))
    x = jnp.zeros((4, 128))

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = analyze_text(_compile_text(f, w, x))
    layer_bytes = 128 * 128 * 4
    # all weight reads ≈ L * layer, definitely << L * (L * layer)
    assert c.hbm_bytes < 3 * L * layer_bytes + 1e6


def test_scan_stash_writes_counted_slicewise():
    """scan ys-stacking (the activation stash) writes one slice per
    step, not the whole stacked buffer per step."""
    L = 16
    x = jnp.zeros((256, 256))

    def f(x):
        def body(h, _):
            h = h * 1.5
            return h, h          # stash every step
        _, ys = jax.lax.scan(body, x, None, length=L)
        return ys

    c = analyze_text(_compile_text(f, x))
    step_bytes = 256 * 256 * 4
    full = L * step_bytes
    # read h + write h + write stash slice per step ~ 3*step_bytes*L;
    # the broken accounting would be ~ L * full = L^2 * step_bytes
    assert c.hbm_bytes < 8 * full
    assert c.hbm_bytes >= 2 * full


def test_collectives_require_mesh_module():
    # module without collectives reports zero
    txt = _compile_text(lambda a: a * 2, jnp.zeros((8, 8)))
    c = analyze_text(txt)
    assert c.coll_bytes == 0
