"""Property-based tests (hypothesis): for ANY randomly composed level-1
dataflow graph, the fused dataflow execution, the no-dataflow execution
and the pure-jnp reference must agree — the system's core invariant
(fusion never changes semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI tier-1)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Program  # noqa: E402

ELTWISE = ["axpy", "scal", "waxpby", "vsub"]
REDUCE = ["dot", "asum", "nrm2"]


@st.composite
def random_chain_spec(draw):
    """A random chain of 1-4 eltwise routines, optionally ending in a
    reduction, with random literal scalars."""
    n_elt = draw(st.integers(1, 4))
    end_reduce = draw(st.booleans())
    routines = []
    for i in range(n_elt):
        blas = draw(st.sampled_from(ELTWISE))
        r = {"blas": blas, "name": f"e{i}"}
        scal = {}
        for s in {"axpy": ["alpha"], "scal": ["alpha"],
                  "waxpby": ["alpha", "beta"], "vsub": []}[blas]:
            scal[s] = draw(st.floats(-2.0, 2.0, allow_nan=False,
                                     width=32))
        if scal:
            r["scalars"] = scal
        if i > 0:
            # chain: previous out feeds this x
            routines[-1]["connections"] = {"out": f"e{i}.x"}
        routines.append(r)
    if end_reduce:
        blas = draw(st.sampled_from(REDUCE))
        routines[-1]["connections"] = {"out": "red.x"}
        routines.append({"blas": blas, "name": "red"})
    return {"dtype": "float32", "routines": routines,
            "window_size": draw(st.sampled_from([128, 256]))}


@given(spec=random_chain_spec(),
       n=st.sampled_from([64, 257, 1024]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_fusion_is_semantics_preserving(spec, n, seed):
    progs = {m: Program.from_spec(spec, mode=m)
             for m in ("dataflow", "nodataflow", "reference")}
    names = progs["dataflow"].input_names
    key = jax.random.PRNGKey(seed)
    inputs = {}
    for i, name in enumerate(sorted(names)):
        k = jax.random.fold_in(key, i)
        inputs[name] = jax.random.uniform(k, (n,), minval=-1.0,
                                          maxval=1.0)
    outs = {m: p(**inputs) for m, p in progs.items()}
    for out_name in progs["dataflow"].output_names:
        a = np.asarray(outs["dataflow"][out_name], np.float64)
        b = np.asarray(outs["reference"][out_name], np.float64)
        c = np.asarray(outs["nodataflow"][out_name], np.float64)
        scale = max(1.0, np.abs(b).max())
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4 * scale)
        np.testing.assert_allclose(c, b, rtol=1e-4, atol=1e-4 * scale)


@st.composite
def random_anchored_spec(draw):
    """A gemv/symv anchor followed by a random level-1 tail: 0-2
    element-wise routines then optionally a reduction, every stage
    consuming the previous window on-chip. This is the mixed-level
    shape the anchored fused-kernel generator must keep
    semantics-preserving."""
    anchor = draw(st.sampled_from(["gemv", "symv"]))
    alpha = draw(st.floats(-2.0, 2.0, allow_nan=False, width=32))
    beta = draw(st.floats(-2.0, 2.0, allow_nan=False, width=32))
    routines = [{"blas": anchor, "name": "mv",
                 "scalars": {"alpha": alpha, "beta": beta},
                 "inputs": {"A": "A", "x": "x", "y": "y"},
                 "outputs": {"out": "mv_out"}}]
    n_elt = draw(st.integers(0, 2))
    for i in range(n_elt):
        blas = draw(st.sampled_from(ELTWISE))
        r = {"blas": blas, "name": f"e{i}", "outputs": {"out": f"o{i}"}}
        scal = {}
        for s in {"axpy": ["alpha"], "scal": ["alpha"],
                  "waxpby": ["alpha", "beta"], "vsub": []}[blas]:
            scal[s] = draw(st.floats(-2.0, 2.0, allow_nan=False,
                                     width=32))
        if scal:
            r["scalars"] = scal
        routines[-1]["connections"] = {"out": f"e{i}.x"}
        routines.append(r)
    if draw(st.booleans()):
        blas = draw(st.sampled_from(REDUCE))
        routines[-1]["connections"] = {"out": "red.x"}
        routines.append({"blas": blas, "name": "red",
                         "outputs": {"out": "rout"}})
    return {"dtype": "float32", "routines": routines}


@given(spec=random_anchored_spec(),
       m=st.sampled_from([64, 257, 700]),
       n=st.sampled_from([64, 300]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_anchored_fusion_is_semantics_preserving(spec, m, n, seed):
    if spec["routines"][0]["blas"] == "symv":
        m = n   # symv needs a square matrix
    progs = {md: Program.from_spec(spec, mode=md)
             for md in ("dataflow", "nodataflow", "reference")}
    key = jax.random.PRNGKey(seed)
    inputs = {}
    for i, (name, kind) in enumerate(
            sorted(progs["dataflow"].ir.io.input_kinds.items())):
        k = jax.random.fold_in(key, i)
        if kind == "matrix":
            inputs[name] = jax.random.uniform(k, (m, n), minval=-1.0,
                                              maxval=1.0)
        elif kind == "vector":
            # x rides the columns, everything else the rows
            dim = n if name == "x" else m
            inputs[name] = jax.random.uniform(k, (dim,), minval=-1.0,
                                              maxval=1.0)
        else:
            inputs[name] = jax.random.uniform(k, (), minval=-1.0,
                                              maxval=1.0)
    outs = {md: p(**inputs) for md, p in progs.items()}
    for out_name in progs["dataflow"].output_names:
        b = np.asarray(outs["reference"][out_name], np.float64)
        scale = max(1.0, float(np.abs(b).max()) if b.size else 1.0)
        for md in ("dataflow", "nodataflow"):
            a = np.asarray(outs[md][out_name], np.float64)
            np.testing.assert_allclose(a, b, rtol=1e-3,
                                       atol=1e-3 * scale)


@given(alpha=st.floats(-3.0, 3.0, allow_nan=False, width=32),
       n=st.integers(1, 5000),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_axpydot_any_length_any_alpha(alpha, n, seed):
    """Fused axpydot == oracle for arbitrary (unaligned) lengths."""
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(seed)
    kw, kv, ku = jax.random.split(key, 3)
    w = jax.random.uniform(kw, (n,), minval=-1, maxval=1)
    v = jax.random.uniform(kv, (n,), minval=-1, maxval=1)
    u = jax.random.uniform(ku, (n,), minval=-1, maxval=1)
    got = ops.axpydot(alpha, w, v, u)
    want = ref.axpydot(jnp.float32(alpha), w, v, u)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * max(1.0, float(np.abs(want))))


@st.composite
def random_v2_loop_spec(draw):
    """A random grammar-v2 loop spec exercising cond stages and stack
    state: a GMRES(m) instance with drawn restart depth/stop knobs,
    or a BiCGStab variant with a drawn while rule. Round-tripping
    these through the builder must never move the digest."""
    from repro.solvers import specs as solver_specs
    if draw(st.booleans()):
        return solver_specs.gmres_loop(
            m=draw(st.integers(2, 6)),
            rtol=draw(st.floats(1e-8, 1e-3, allow_nan=False)),
            max_restarts=draw(st.integers(1, 80)),
            name=draw(st.sampled_from(["gmres", "g2", "krylov"])))
    spec = {k: v for k, v in solver_specs.BICGSTAB_LOOP.items()}
    it = dict(spec["iterate"])
    it["while"] = {"metric": "rnorm", "init": "rnorm0",
                   "scale": draw(st.one_of(
                       st.just("bnorm"),
                       st.floats(0.5, 4.0, allow_nan=False))),
                   "rtol": draw(st.floats(1e-9, 1e-2,
                                          allow_nan=False)),
                   "max_iters": draw(st.integers(1, 500))}
    spec["iterate"] = it
    return spec


@given(spec=random_v2_loop_spec())
@settings(max_examples=25, deadline=None)
def test_v2_loop_builder_roundtrip_is_digest_lossless(spec):
    """builder -> to_spec -> from_spec is digest-lossless for specs
    containing cond stages, stack state, and nested iterates, and the
    canonical unparse form is a fixpoint."""
    from repro import blas
    from repro.core import lowering, spec as spec_mod
    once = blas.ProgramBuilder.from_spec(spec).to_spec()
    assert lowering.spec_digest(once) == lowering.spec_digest(spec)
    twice = blas.ProgramBuilder.from_spec(once).to_spec()
    assert lowering.spec_digest(twice) == lowering.spec_digest(spec)
    canon = spec_mod.unparse_loop(spec_mod.parse_loop(spec))
    recanon = spec_mod.unparse_loop(spec_mod.parse_loop(canon))
    assert recanon == canon


@st.composite
def random_gemm_anchored_spec(draw):
    """A gemm anchor with a random tile epilogue: optionally a
    per-column axpy (colaxpy) consuming the accumulator panel,
    optionally a column-dot reduction at the end. The 2-D anchored
    shape the level-3 tile generator must keep semantics-preserving
    for any scalars and (unaligned) panel shapes."""
    alpha = draw(st.floats(-2.0, 2.0, allow_nan=False, width=32))
    beta = draw(st.floats(-2.0, 2.0, allow_nan=False, width=32))
    routines = [{"blas": "gemm", "name": "mm",
                 "scalars": {"alpha": alpha, "beta": beta},
                 "inputs": {"A": "A", "B": "B", "C": "C0"},
                 "outputs": {"out": "Q"}}]
    if draw(st.booleans()):
        routines[-1]["connections"] = {"out": "up.x"}
        routines.append({"blas": "colaxpy", "name": "up",
                         "inputs": {"a": "al", "y": "Y0"},
                         "outputs": {"out": "R"}})
    if draw(st.booleans()):
        routines[-1]["connections"] = {"out": ["cd.x", "cd.y"]}
        routines.append({"blas": "coldot", "name": "cd",
                         "outputs": {"out": "rz"}})
    return {"dtype": "float32", "routines": routines}


@given(spec=random_gemm_anchored_spec(),
       m=st.sampled_from([64, 257, 513]),
       k=st.sampled_from([64, 300]),
       s=st.sampled_from([1, 3, 8]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_gemm_anchored_fusion_is_semantics_preserving(spec, m, k, s,
                                                      seed):
    progs = {md: Program.from_spec(spec, mode=md)
             for md in ("dataflow", "nodataflow", "reference")}
    key = jax.random.PRNGKey(seed)
    shapes = {"A": (m, k), "B": (k, s), "C0": (m, s), "Y0": (m, s),
              "al": (s,)}
    inputs = {}
    for i, name in enumerate(sorted(progs["dataflow"].input_names)):
        inputs[name] = jax.random.uniform(
            jax.random.fold_in(key, i), shapes[name],
            minval=-1.0, maxval=1.0)
    outs = {md: p(**inputs) for md, p in progs.items()}
    for out_name in progs["dataflow"].output_names:
        b = np.asarray(outs["reference"][out_name], np.float64)
        scale = max(1.0, float(np.abs(b).max()) if b.size else 1.0)
        for md in ("dataflow", "nodataflow"):
            a = np.asarray(outs[md][out_name], np.float64)
            np.testing.assert_allclose(a, b, rtol=1e-3,
                                       atol=1e-3 * scale)
