"""Compile-once gate, promoted from the solver benchmark into tier-1.

The driver wraps every iteration in one jitted `lax.while_loop`, so a
whole solve must trace its body exactly once — a retrace per iteration
is the regression the benchmark's trace-count gate was built to catch,
and this file makes the same invariant fail fast under pytest for all
four JSON loop specs. Recompiling the same spec must also hit the
digest-keyed lowering cache: the body programs compile once per
process, not once per Executable.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import blas
from repro.core import lowering
from repro.solvers import specs
from repro.solvers.iterative import jacobi_dinv
from repro.tune import config as tile_config
from repro.tune import store as tune_store

N = 24


def _spd(n, seed=0):
    k = jax.random.PRNGKey(seed)
    m = jax.random.normal(k, (n, n), jnp.float32)
    return m @ m.T / n + jnp.eye(n, dtype=jnp.float32)


def _nonsym(n, seed=3):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (n, n), jnp.float32) / jnp.sqrt(n) \
        + 3.0 * jnp.eye(n)


def _diag_dominant(n, seed=0):
    a = _spd(n, seed)
    return a + 2.0 * jnp.diag(jnp.sum(jnp.abs(a), axis=1))


def _case(name):
    x0 = jnp.zeros(N, jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (N,), jnp.float32)
    if name == "cg":
        return specs.CG_LOOP, {"A": _spd(N), "b": b, "x0": x0}
    if name == "jacobi":
        A = _diag_dominant(N)
        return specs.JACOBI_LOOP, {"A": A, "b": b, "x0": x0,
                                   "dinv": jacobi_dinv(A),
                                   "omega": jnp.float32(1.0)}
    if name == "bicgstab":
        return specs.BICGSTAB_LOOP, {"A": _nonsym(N), "b": b, "x0": x0}
    assert name == "gmres"
    return specs.GMRES_LOOP, {"A": _nonsym(N), "b": b, "x0": x0}


@pytest.mark.parametrize("name", ["cg", "jacobi", "bicgstab", "gmres"])
def test_loop_body_traces_once(name):
    """tol=0 forces the full max_iters iterations (no early exit), so
    a per-iteration retrace cannot hide behind fast convergence."""
    spec, ops = _case(name)
    max_iters = 2 if name == "gmres" else 4   # one gmres iter = restart
    exe = blas.compile(spec, max_iters=max_iters)
    res = exe.run(tol=0.0, **ops)
    assert res.x.shape == (N,)
    assert int(res.iterations) == max_iters
    assert exe.trace_count == 1
    # more solves through the same handle still never retrace
    exe.run(tol=0.0, **ops)
    assert exe.trace_count == 1


@pytest.mark.parametrize("name", ["cg", "jacobi", "bicgstab", "gmres"])
def test_recompile_hits_lowering_cache(name):
    spec, ops = _case(name)
    max_iters = 2 if name == "gmres" else 4
    blas.compile(spec, max_iters=max_iters).run(tol=0.0, **ops)
    before = lowering.cache_stats()
    exe = blas.compile(spec, max_iters=max_iters)
    exe.run(tol=0.0, **ops)
    after = lowering.cache_stats()
    # every body/setup stage program of the recompile is a cache hit
    assert after["hits"] > before["hits"]
    assert after["misses"] == before["misses"]
    assert exe.trace_count == 1


def test_block_cg_loop_body_traces_once():
    """The matrix-state loop spec rides the same single-trace driver:
    a whole block solve (s right-hand sides) traces its gemm-anchored
    body exactly once."""
    s = 3
    B = jax.random.normal(jax.random.PRNGKey(2), (N, s), jnp.float32)
    ops = {"A": _spd(N), "B": B,
           "x0": jnp.zeros((N, s), jnp.float32)}
    exe = blas.compile(specs.BLOCK_CG_LOOP, max_iters=4)
    res = exe.run(tol=0.0, **ops)
    assert res.x.shape == (N, s)
    assert int(res.iterations) == 4
    assert exe.trace_count == 1
    exe.run(tol=0.0, **ops)
    assert exe.trace_count == 1


def test_guarded_and_faulted_compiles_trace_once():
    """The in-loop guards compile into the same single body trace —
    no retrace from the status plumbing — and a fault-armed compile
    (which bypasses the clean cache) also traces exactly once."""
    from repro.guard import chaos

    spec, ops = _case("cg")
    assert spec["iterate"].get("guards")      # guards ship on
    exe = blas.compile(spec, max_iters=4)
    res = exe.run(tol=0.0, **ops)
    assert res.status is not None
    assert exe.trace_count == 1
    exe.run(tol=0.0, **ops)
    assert exe.trace_count == 1

    plan = chaos.FaultPlan(program="cg", kind="nan", iteration=1)
    fexe = blas.compile(spec, max_iters=8, fault=plan)
    fres = fexe.run(tol=1e-6, **ops)
    assert fres.status_names() == "NONFINITE"
    assert fexe.trace_count == 1


def test_trace_once_with_tuning_table_tiles(monkeypatch, tmp_path):
    """Compile-once must survive tiles coming from the tuning table:
    seed a tuned artifact for every stage of the CG loop, recompile
    with the (default) tiles="auto", and assert the tile plans were
    picked up without any extra body trace."""
    monkeypatch.setenv(tune_store.ENV_CACHE_DIR, str(tmp_path))
    tune_store.reset_store()
    lowering.clear_cache()
    try:
        spec, ops = _case("cg")
        exe = blas.compile(spec, max_iters=4)

        # seed a wildcard winner for each distinct stage program (a
        # 128-block clamps onto N=24, so numerics cannot change)
        cfg = tile_config.TileConfig(block_m=128, block_n=128)
        plan = tile_config.TilePlan.everywhere(cfg)
        store = tune_store.get_store()
        dk = tile_config.current_device_kind()

        def visit(compiled):
            for st in compiled:
                if st.tag == "program":
                    # fuse/anchor normalize to True in dataflow mode
                    store.put_artifact(st.ir.digest, "dataflow", True,
                                       True, dk, spec=st.ir.raw,
                                       plan=plan, tuned=True)
                elif st.tag == "cond":
                    visit(st.then)
                    visit(st.orelse)
                elif st.tag == "loop":
                    visit(st.body)

        lir = exe._impl.lir
        visit(lir.setup)
        visit(lir.body)

        lowering.clear_cache()
        tuned = blas.compile(spec, max_iters=4)
        planned = []

        def collect(compiled):
            for st in compiled:
                if st.tag == "program":
                    planned.append(bool(st.ir.tile_plan))
                elif st.tag == "cond":
                    collect(st.then)
                    collect(st.orelse)
                elif st.tag == "loop":
                    collect(st.body)

        collect(tuned._impl.lir.setup)
        collect(tuned._impl.lir.body)
        assert planned and all(planned)   # every stage got its plan
        res = tuned.run(tol=0.0, **ops)
        assert res.x.shape == (N,)
        assert tuned.trace_count == 1
        tuned.run(tol=0.0, **ops)
        assert tuned.trace_count == 1
    finally:
        monkeypatch.delenv(tune_store.ENV_CACHE_DIR)
        tune_store.reset_store()
        lowering.clear_cache()
