"""Sharding-rule invariants (no devices needed: specs are static).

Every generated PartitionSpec must (a) only name real mesh axes,
(b) only shard divisible dims, (c) never shard the stacked layer dim.
Checked for all 10 archs x both styles x both meshes via eval_shape.
"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as M
from repro.models import sharding as S


class _FakeMesh:
    """Mesh stand-in: axis names + sizes only (what the rules read)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESHES = {
    "pod16x16": _FakeMesh({"data": 16, "model": 16}),
    "multipod": _FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        assert a in mesh.axis_names, f"unknown axis {a}"
        n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("style", ["2d", "fsdp"])
def test_param_specs_are_valid(arch, mesh_name, style):
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = S.param_specs(cfg, mesh, shapes, style=style)

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, spec):
            n = _axis_size(mesh, entry)
            assert dim % n == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(check, shapes, specs)


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x22b",
                                  "hymba-1.5b", "minicpm3-4b"])
def test_cache_specs_are_valid(arch):
    from repro.configs import SHAPES
    cfg = get_config(arch)
    mesh = MESHES["pod16x16"]
    shape = SHAPES["decode_32k"]
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = S.cache_specs(cfg, mesh, cache_shapes,
                          batch=shape.global_batch)

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, spec):
            n = _axis_size(mesh, entry)
            assert dim % n == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(check, cache_shapes, specs)


def test_model_flops_sane():
    """6·N·D consistency: train flops = 3x prefill flops per token."""
    from repro.configs import SHAPES
    from repro.launch.roofline import model_flops_for
    cfg = get_config("llama3-8b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    pf = model_flops_for(cfg, SHAPES["prefill_32k"])
    tok_tr = SHAPES["train_4k"].global_batch * 4096
    tok_pf = SHAPES["prefill_32k"].global_batch * 32768
    assert tr / tok_tr == pytest.approx(3 * pf / tok_pf)
    # MoE uses active params
    mx = get_config("mixtral-8x22b")
    assert mx.n_active_params() < 0.35 * mx.n_params()
