"""Shape/dtype sweeps: every BLAS Pallas kernel vs its ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]
VEC_SIZES = [7, 128, 1000, 4096, 100_000]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


def _vecs(n, dtype, k, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return [jax.random.normal(key, (n,), dtype=dtype) for key in keys]


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_axpy(n, dtype):
    x, y = _vecs(n, dtype, 2)
    got = ops.axpy(1.7, x, y)
    np.testing.assert_allclose(got, ref.axpy(jnp.asarray(1.7, dtype), x, y),
                               **_tol(dtype))


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_scal(n, dtype):
    (x,) = _vecs(n, dtype, 1)
    np.testing.assert_allclose(ops.scal(-0.3, x),
                               ref.scal(jnp.asarray(-0.3, dtype), x),
                               **_tol(dtype))


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_waxpby(n, dtype):
    x, y = _vecs(n, dtype, 2)
    got = ops.waxpby(0.5, x, -1.25, y)
    want = ref.waxpby(jnp.asarray(0.5, dtype), x,
                      jnp.asarray(-1.25, dtype), y)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dot(n, dtype):
    x, y = _vecs(n, dtype, 2)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(ops.dot(x, y), ref.dot(x, y), rtol=rtol,
                               atol=1e-2 * np.sqrt(n))


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_asum_nrm2(n, dtype):
    (x,) = _vecs(n, dtype, 1)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(ops.asum(x), ref.asum(x), rtol=rtol)
    np.testing.assert_allclose(ops.nrm2(x), ref.nrm2(x), rtol=rtol)


@pytest.mark.parametrize("n", [64, 1000, 40_000])
@pytest.mark.parametrize("dtype", DTYPES)
def test_axpydot_fused_matches_oracle_and_nodf(n, dtype):
    w, v, u = _vecs(n, dtype, 3)
    alpha = 0.9
    want = ref.axpydot(jnp.asarray(alpha, dtype), w, v, u)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    atol = 1e-2 * np.sqrt(n)
    np.testing.assert_allclose(ops.axpydot(alpha, w, v, u), want,
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(ops.axpydot_nodf(alpha, w, v, u), want,
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("m,n", [(8, 128), (100, 300), (512, 512),
                                 (1000, 257)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gemv(m, n, dtype):
    key = jax.random.PRNGKey(1)
    ka, kx, ky = jax.random.split(key, 3)
    a = jax.random.normal(ka, (m, n), dtype=dtype)
    x = jax.random.normal(kx, (n,), dtype=dtype)
    y = jax.random.normal(ky, (m,), dtype=dtype)
    got = ops.gemv(1.1, a, x, 0.7, y)
    want = ref.gemv(1.1, a, x, 0.7, y)
    tol = dict(rtol=3e-2, atol=3e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (64, 64, 64),
                                   (130, 257, 100), (512, 384, 256)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gemm(m, k, n, dtype):
    key = jax.random.PRNGKey(2)
    ka, kb, kc = jax.random.split(key, 3)
    a = jax.random.normal(ka, (m, k), dtype=dtype)
    b = jax.random.normal(kb, (k, n), dtype=dtype)
    c = jax.random.normal(kc, (m, n), dtype=dtype)
    got = ops.gemm(0.8, a, b, 1.2, c, block_m=128, block_n=128, block_k=128)
    want = ref.gemm(0.8, a, b, 1.2, c)
    tol = dict(rtol=3e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul(dtype):
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (96, 160), dtype=dtype)
    b = jax.random.normal(key, (160, 224), dtype=dtype)
    got = ops.matmul(a, b, block_m=64, block_n=128, block_k=128)
    want = ref.matmul(a, b)
    tol = dict(rtol=3e-2, atol=5e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_composites(dtype):
    key = jax.random.PRNGKey(4)
    ka, kb, kx, kp, kr = jax.random.split(key, 5)
    m, n = 96, 160
    a = jax.random.normal(ka, (m, n), dtype=dtype)
    b = jax.random.normal(kb, (m, n), dtype=dtype)
    x = jax.random.normal(kx, (n,), dtype=dtype)
    p = jax.random.normal(kp, (n,), dtype=dtype)
    r = jax.random.normal(kr, (m,), dtype=dtype)
    np.testing.assert_allclose(ops.gesummv(0.4, a, 0.6, b, x),
                               ref.gesummv(0.4, a, 0.6, b, x),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(ops.atax(a, x), ref.atax(a, x),
                               rtol=1e-4, atol=1e-2)
    q_got, s_got = ops.bicgk(a, p, r)
    q_want, s_want = ref.bicgk(a, p, r)
    np.testing.assert_allclose(q_got, q_want, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(s_got, s_want, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# PR1 registry growth: copy / vmul / rot / iamax / symv
# Property style: seeded sweeps over random shapes and values, kernel
# vs reference, plus fused-vs-unfused parity through Program specs.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_copy(n, dtype):
    (x,) = _vecs(n, dtype, 1)
    got = ops.copy(x)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


@pytest.mark.parametrize("n", VEC_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_vmul(n, dtype):
    x, y = _vecs(n, dtype, 2)
    np.testing.assert_allclose(ops.vmul(x, y), ref.vmul(x, y),
                               **_tol(dtype))


@pytest.mark.parametrize("seed", range(5))
def test_rot_property(seed):
    """Random sizes/angles: kernel matches oracle and preserves the
    rotation invariant x'² + y'² = x² + y² elementwise."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 5000))
    theta = float(rng.uniform(0, 2 * np.pi))
    c, s = float(np.cos(theta)), float(np.sin(theta))
    x, y = _vecs(n, jnp.float32, 2, seed=seed)
    gx, gy = ops.rot(c, s, x, y)
    wx, wy = ref.rot(c, s, x, y)
    np.testing.assert_allclose(gx, wx, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gy, wy, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gx * gx + gy * gy, x * x + y * y,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", range(5))
def test_iamax_property(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(1, 20_000))
    (x,) = _vecs(n, jnp.float32, 1, seed=seed)
    assert int(ops.iamax(x)) == int(ref.iamax(x))


def test_iamax_ties_and_edges():
    # first occurrence wins on ties (BLAS isamax semantics)
    t = jnp.array([1.0, -3.0, 3.0, 0.5])
    assert int(ops.iamax(t)) == 1
    assert int(ops.iamax(jnp.zeros(1000))) == 0
    assert int(ops.iamax(jnp.array([7.0]))) == 0
    # max in the zero-padded tail region of the last window
    x = jnp.zeros(1000).at[999].set(-5.0)
    assert int(ops.iamax(x)) == 999


def test_iamax_beyond_f32_mantissa_range():
    """The index accumulator is int32: positions past 2^24 (where f32
    lane carries stop being exact — the old cap) must round-trip
    exactly, including a decoy maximum below the boundary."""
    n = (1 << 24) + 4097
    target = n - 14      # odd and > 2^24: not exactly f32-representable
    assert float(np.float32(target)) != target
    x = jnp.zeros(n, jnp.float32).at[target].set(3.5).at[123].set(3.25)
    assert int(ops.iamax(x, block_rows=8192)) == target
    # tie across the 2^24 boundary: the first (small-index) wins
    x = jnp.zeros(n, jnp.float32).at[target].set(2.0).at[77].set(2.0)
    assert int(ops.iamax(x, block_rows=8192)) == 77


@pytest.mark.parametrize("n", [8, 100, 257, 512])
@pytest.mark.parametrize("dtype", DTYPES)
def test_symv(n, dtype):
    key = jax.random.PRNGKey(11)
    ka, kx, ky = jax.random.split(key, 3)
    a = jax.random.normal(ka, (n, n), dtype=dtype)
    x = jax.random.normal(kx, (n,), dtype=dtype)
    y = jax.random.normal(ky, (n,), dtype=dtype)
    got = ops.symv(1.3, a, x, -0.6, y, block=128)
    want = ref.symv(1.3, a, x, -0.6, y)
    tol = dict(rtol=3e-2, atol=3e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_symv_ignores_upper_triangle():
    """Only the lower triangle may be referenced."""
    key = jax.random.PRNGKey(12)
    a = jax.random.normal(key, (100, 100))
    x = jax.random.normal(jax.random.fold_in(key, 1), (100,))
    y = jnp.zeros(100)
    garbage = a + jnp.triu(jnp.full((100, 100), 1e6), k=1)
    np.testing.assert_allclose(ops.symv(1.0, a, x, 0.0, y, block=64),
                               ops.symv(1.0, garbage, x, 0.0, y, block=64),
                               rtol=1e-6)


@pytest.mark.parametrize("mode", ["dataflow", "nodataflow", "reference"])
@pytest.mark.parametrize("seed", range(3))
def test_new_routines_fused_vs_unfused(mode, seed):
    """copy/vmul/rot/iamax composed in one spec: identical results
    whether the planner fuses them into one generated kernel
    (dataflow), runs one kernel per routine (nodataflow), or takes the
    jnp oracle path (reference)."""
    from repro.core import Program

    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(2, 3000))
    theta = float(rng.uniform(0, 2 * np.pi))
    c, s = float(np.cos(theta)), float(np.sin(theta))
    x, y = _vecs(n, jnp.float32, 2, seed=seed)

    spec = {"routines": [
        {"blas": "copy", "name": "cp", "inputs": {"x": "x"},
         "connections": {"out": "g.x"}},
        {"blas": "rot", "name": "g", "scalars": {"c": c, "s": s},
         "inputs": {"y": "y"},
         "connections": {"out_x": ["h.x", "im.x"], "out_y": "h.y"},
         "outputs": {"out_y": "yr"}},
        {"blas": "vmul", "name": "h", "outputs": {"out": "prod"}},
        {"blas": "iamax", "name": "im", "outputs": {"out": "idx"}},
    ]}
    prog = Program.from_spec(spec, mode=mode)
    out = prog(x=x, y=y)
    wx, wy = ref.rot(c, s, x, y)
    np.testing.assert_allclose(out["yr"], wy, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out["prod"], wx * wy, rtol=1e-4,
                               atol=1e-5)
    assert int(out["idx"]) == int(ref.iamax(wx))


@pytest.mark.parametrize("mode", ["dataflow", "nodataflow", "reference"])
def test_symv_through_program(mode):
    from repro.core import Program

    key = jax.random.PRNGKey(13)
    a = jax.random.normal(key, (300, 300))
    x = jax.random.normal(jax.random.fold_in(key, 1), (300,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (300,))
    spec = {"routines": [
        {"blas": "symv", "name": "sv",
         "scalars": {"alpha": 1.5, "beta": -0.5},
         "inputs": {"A": "A", "x": "x", "y": "y"},
         "outputs": {"out": "out"}}]}
    out = Program.from_spec(spec, mode=mode)(A=a, x=x, y=y)
    np.testing.assert_allclose(out["out"], ref.symv(1.5, a, x, -0.5, y),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m,n", [(8, 128), (100, 300)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_ger(m, n, dtype):
    key = jax.random.PRNGKey(7)
    kx, ky, ka = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m,), dtype=dtype)
    y = jax.random.normal(ky, (n,), dtype=dtype)
    a = jax.random.normal(ka, (m, n), dtype=dtype)
    got = ops.ger(0.5, x, y, a)
    want = ref.ger(0.5, x, y, a)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)
