"""repro.verify: one triggering golden per diagnostic code (asserting
the code and its JSON path), clean passes over every shipped spec, the
raising/reporting API contract, and the CLI."""
import json

import pytest

from repro import verify
from repro.core import lowering, spec as spec_mod
from repro.core.spec import SpecError
from repro.solvers import specs
from repro.verify import VerifyError


def _loop(**over):
    """Minimal valid loop spec (Richardson on A) to mutate. Its
    `x -> x` feedback edge intentionally trips the RV204 lint."""
    base = {
        "name": "mini",
        "operands": {"A": "matrix", "b": "vector", "x0": "vector"},
        "setup": [
            {"program": specs.NRM2, "inputs": {"x": "b"},
             "outputs": {"norm": "bnorm"}},
            {"program": specs.RESIDUAL, "inputs": {"x": "x0"},
             "outputs": {"r": "r0", "rnorm": "rnorm0"}},
        ],
        "iterate": {
            "state": {"x": {"init": "x0"}, "r": {"init": "r0"}},
            "body": [
                {"program": specs.RESIDUAL, "inputs": {"x": "x"},
                 "outputs": {"r": "r_next", "rnorm": "rnorm"}},
            ],
            "feedback": {"x": "x", "r": "r_next"},
            "while": {"metric": "rnorm", "init": "rnorm0",
                      "scale": "bnorm", "max_iters": 5},
            "solution": {"x": "x"},
        },
    }
    base.update(over)
    return base


def _body(*stages):
    bad = _loop()
    bad["iterate"] = {
        **bad["iterate"],
        "body": list(stages) + bad["iterate"]["body"],
    }
    return bad


def _find(report, code):
    hits = report.by_code(code)
    assert hits, (f"expected {code} in "
                  f"{[d.code for d in report.diagnostics]}")
    return hits[0]


def _assert_fires(raw, code, path, *, severity="error",
                  mode="dataflow"):
    report = verify.analyze(raw, mode=mode)
    d = _find(report, code)
    assert d.severity == severity
    assert d.path == path, f"{code}: {d.path!r} != {path!r}"
    return report


# ---------------------------------------------------------------------------
# Golden broken specs: every diagnostic code fires with its JSON path
# ---------------------------------------------------------------------------


def test_rv100_no_routines():
    _assert_fires({"routines": []}, "RV100", "routines")


def test_rv101_unknown_routine():
    _assert_fires({"routines": [{"blas": "nope", "name": "n"}]},
                  "RV101", "routines[0].blas")


def test_rv102_duplicate_routine_name():
    _assert_fires(
        {"routines": [{"blas": "dot", "name": "d"},
                      {"blas": "dot", "name": "d"}]},
        "RV102", "routines[1].name")


def test_rv103_unknown_port():
    _assert_fires(
        {"routines": [{"blas": "dot", "name": "d",
                       "connections": {"nope": ["d.x"]}}]},
        "RV103", "routines[0].connections.nope")


def test_rv104_bad_connection_target():
    _assert_fires(
        {"routines": [{"blas": "scal", "name": "s",
                       "connections": {"out": ["zz.x"]}},
                      {"blas": "dot", "name": "d"}]},
        "RV104", "routines[0].connections.out")


def test_rv105_scalar_output_feeds_window_port():
    _assert_fires(
        {"routines": [{"blas": "dot", "name": "d",
                       "connections": {"out": ["s.x"]}},
                      {"blas": "scal", "name": "s"}]},
        "RV105", "routines[0].connections.out")


def test_rv106_port_driven_twice():
    _assert_fires(
        {"routines": [{"blas": "scal", "name": "sc",
                       "connections": {"out": ["d.x", "d.x"]}},
                      {"blas": "dot", "name": "d"}]},
        "RV106", "routines[0].connections.out")


def test_rv107_dataflow_cycle():
    _assert_fires(
        {"routines": [{"blas": "copy", "name": "c1",
                       "connections": {"out": ["c2.x"]}},
                      {"blas": "copy", "name": "c2",
                       "connections": {"out": ["c1.x"]}}]},
        "RV107", "routines")


def test_rv108_conflicting_input_kinds():
    _assert_fires(
        {"routines": [{"blas": "axpy", "name": "a",
                       "scalars": {"alpha": {"input": "v"}},
                       "inputs": {"x": "v"}}]},
        "RV108", "routines[0]")


def test_rv109_duplicate_output_name():
    _assert_fires(
        {"routines": [{"blas": "scal", "name": "s1",
                       "outputs": {"out": "y"}},
                      {"blas": "scal", "name": "s2",
                       "outputs": {"out": "y"}}]},
        "RV109", "routines[1].outputs.out")


def test_rv110_reduced_precision_reduction():
    _assert_fires(
        {"dtype": "bfloat16",
         "routines": [{"blas": "dot", "name": "d"}]},
        "RV110", "routines[0]", severity="warning")


def test_rv111_unsupported_dtype():
    _assert_fires(
        {"dtype": "float64",
         "routines": [{"blas": "dot", "name": "d"}]},
        "RV111", "dtype")


def test_rv112_bad_vector_width():
    _assert_fires(
        {"vector_width": 100,
         "routines": [{"blas": "dot", "name": "d"}]},
        "RV112", "vector_width")


def test_rv112_per_routine_override_checked_too():
    # regression: per-routine overrides used to skip the lane check
    _assert_fires(
        {"routines": [{"blas": "dot", "name": "d",
                       "vector_width": 100}]},
        "RV112", "routines[0].vector_width")


def test_rv201_undefined_name():
    bad = _body({"let": {"z": "nosuch * 2"}})
    _assert_fires(bad, "RV201", "iterate.body[0].z")


def test_rv202_rebind():
    bad = _loop()
    bad["iterate"] = {
        **bad["iterate"],
        "body": bad["iterate"]["body"] + [
            {"program": specs.RESIDUAL, "inputs": {"x": "x"},
             "outputs": {"r": "r_next", "rnorm": "rn2"}},
        ],
    }
    _assert_fires(bad, "RV202", "iterate.body[1]")


def test_rv203_dead_binding():
    bad = _body({"let": {"unused": "rnorm0 * 2"}})
    _assert_fires(bad, "RV203", "iterate.body[0].unused",
                  severity="warning")


def test_rv203_underscore_opts_out():
    bad = _body({"let": {"_scratch": "rnorm0 * 2"}})
    assert not verify.analyze(bad).by_code("RV203")


def test_rv204_feedback_never_updated():
    # the base fixture's x -> x edge is exactly this lint
    _assert_fires(_loop(), "RV204", "iterate.feedback.x",
                  severity="warning")


def test_rv205_constant_cond_predicate():
    bad = _body({"cond": {"if": "1 <= 2",
                          "then": [{"let": {"z": "1"}}],
                          "else": [{"let": {"z": "2"}}]}})
    _assert_fires(bad, "RV205", "iterate.body[0].cond.if",
                  severity="warning")


def _stacked(*stages, slots=3):
    bad = _loop()
    bad["iterate"] = {
        **bad["iterate"],
        "state": {**bad["iterate"]["state"],
                  "S": {"kind": "stack", "slots": slots,
                        "of": "scalar"}},
        "body": [{"let": {"one": "1"}}] + list(stages)
        + bad["iterate"]["body"],
    }
    return bad


def test_rv206_provably_out_of_range_store():
    bad = _stacked({"store": {"into": "S", "slot": "5",
                              "value": "one"}})
    _assert_fires(bad, "RV206", "iterate.body[1].store.slot")


def test_rv206_counter_range_overflow_warns():
    # j runs 0..4 against a 3-slot stack: only the upper end violates
    bad = _stacked({"iterate": {
        "counter": "j",
        "state": {"h": {"init": "rnorm0"}},
        "body": [{"read": {"name": "sj", "from": "S", "slot": "j"}},
                 {"let": {"h2": "h * sj"}}],
        "feedback": {"h": "h2"},
        "while": {"count": 5},
    }})
    d = _find(verify.analyze(bad), "RV206")
    assert d.severity == "warning"
    assert d.path == "iterate.body[1].iterate.body[0].read.slot"


def test_rv207_reserved_threshold():
    bad = _loop()
    bad["operands"] = {**bad["operands"], "threshold": "scalar"}
    _assert_fires(bad, "RV207", "iterate.state")


def test_rv208_store_kind_mismatch():
    bad = _stacked({"store": {"into": "S", "slot": "0", "value": "r"}})
    _assert_fires(bad, "RV208", "iterate.body[1].store.value")


def test_rv209_metric_not_produced():
    bad = _loop()
    bad["iterate"] = {**bad["iterate"],
                      "while": {"metric": "bnorm", "init": "rnorm0",
                                "max_iters": 5}}
    _assert_fires(bad, "RV209", "iterate.while.metric")


def test_rv210_store_inside_cond():
    bad = _stacked({"cond": {
        "if": "rnorm0 <= 1",
        "then": [{"store": {"into": "S", "slot": "0", "value": "one"}},
                 {"let": {"z": "1"}}],
        "else": [{"let": {"z": "2"}}]}})
    _assert_fires(bad, "RV210",
                  "iterate.body[1].cond.then[0].store")


def test_rv211_unknown_program_input_binding():
    bad = _loop()
    bad["iterate"] = {
        **bad["iterate"],
        "body": [{"program": specs.RESIDUAL,
                  "inputs": {"nope": "x"},
                  "outputs": {"r": "r_next", "rnorm": "rnorm"}}],
    }
    _assert_fires(bad, "RV211", "iterate.body[0]")


def test_rv301_division_by_constant_zero():
    bad = _body({"let": {"z": "rnorm0 / (2 - 2)"}})
    _assert_fires(bad, "RV301", "iterate.body[0].z")


def test_rv302_sqrt_of_negative_constant():
    bad = _body({"let": {"z": "sqrt(0 - 1)"}})
    _assert_fires(bad, "RV302", "iterate.body[0].z")


def test_rv302_unprovable_sqrt_warns():
    bad = _body({"let": {"z": "sqrt(rnorm0 - 1)"}})
    d = _find(verify.analyze(bad), "RV302")
    assert d.severity == "warning"
    assert d.path == "iterate.body[0].z"


def test_rv302_square_sum_is_provably_safe():
    ok = _body({"let": {"z": "sqrt(rnorm0 * rnorm0 + 1)"}})
    assert not verify.analyze(ok).by_code("RV302")


def test_rv303_runtime_denominator_is_info():
    bad = _body({"let": {"z": "rnorm0 / bnorm"}})
    _assert_fires(bad, "RV303", "iterate.body[0].z", severity="info")


def test_rv401_vmem_budget_exceeded():
    # 4096^2 f32 matrix windows on every gemm port: ~256 MiB >> 16 MiB
    _assert_fires(
        {"window_size": 4096,
         "routines": [{"blas": "gemm", "name": "g"}]},
        "RV401", "routines[0]")


def test_rv402_window_not_vector_width_aligned():
    _assert_fires(
        {"window_size": 200,
         "routines": [{"blas": "dot", "name": "d"}]},
        "RV402", "routines[0].window_size", severity="warning")


def test_rv403_duplicate_slot_store():
    bad = _stacked(
        {"store": {"into": "S", "slot": "0", "value": "one"}},
        {"store": {"into": "S", "slot": "0", "value": "one"}})
    _assert_fires(bad, "RV403", "iterate.body[2].store",
                  severity="warning")


def test_rv504_matrix_state_feedback_mismatch():
    """Feeding a scalar back into block-CG's (n, s) iterate panel is
    the matrix-specific RV504, not the generic RV208 kind error."""
    import copy

    bad = copy.deepcopy(specs.BLOCK_CG_LOOP)
    bad["iterate"]["feedback"]["x"] = bad["iterate"]["while"]["metric"]
    _assert_fires(bad, "RV504", "iterate.feedback.x")


def test_catalog_covers_every_emitted_code():
    assert set(verify.CATALOG) >= {
        "RV100", "RV101", "RV102", "RV103", "RV104", "RV105", "RV106",
        "RV107", "RV108", "RV109", "RV110", "RV111", "RV112", "RV201",
        "RV202", "RV203", "RV204", "RV205", "RV206", "RV207", "RV208",
        "RV209", "RV210", "RV211", "RV301", "RV302", "RV303", "RV401",
        "RV402", "RV403", "RV504"}


# ---------------------------------------------------------------------------
# Clean pass: every shipped spec verifies with zero errors/warnings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,raw", [
    ("CG_LOOP", specs.CG_LOOP),
    ("JACOBI_LOOP", specs.JACOBI_LOOP),
    ("BICGSTAB_LOOP", specs.BICGSTAB_LOOP),
    ("GMRES_LOOP", specs.GMRES_LOOP),
    ("BLOCK_CG_LOOP", specs.BLOCK_CG_LOOP),
])
def test_shipped_loop_specs_verify_clean(name, raw):
    report = verify.analyze(raw)
    assert report.errors == (), report.format()
    assert report.warnings == (), report.format()


def test_all_routine_specs_verify_clean():
    from repro.blas import functional
    from repro.core import routines as R
    for name in R.names():
        report = verify.analyze(functional.routine_spec(name))
        assert report.ok and not report.warnings, report.format()


# ---------------------------------------------------------------------------
# API contract: raising gate, multi-error reports, opt-out
# ---------------------------------------------------------------------------


def test_verify_error_carries_all_diagnostics():
    bad = _body({"let": {"z": "nosuch * 2"}},
                {"let": {"w": "alsomissing + 1"}})
    with pytest.raises(VerifyError) as ei:
        lowering.lower_loop(bad)
    report = ei.value.report
    assert len(report.by_code("RV201")) == 2
    # the exception reproduces the raise-site messages verbatim
    assert "not defined" in str(ei.value)
    assert ei.value.code == "RV201"


def test_verify_error_is_a_spec_error():
    with pytest.raises(SpecError):
        lowering.lower({"routines": []})


def test_malformed_spec_fails_with_zero_jax_frames():
    bad = _body({"let": {"z": "nosuch * 2"}})
    with pytest.raises(VerifyError) as ei:
        lowering.lower_loop(bad)
    frames = ei.traceback
    assert not any("/jax/" in str(f.path) or "/jax_" in str(f.path)
                   for f in frames), [str(f.path) for f in frames]


def test_verify_false_preserves_raise_at_first_site():
    bad = _body({"let": {"z": "nosuch * 2"}})
    with pytest.raises(SpecError) as ei:
        lowering.lower_loop(bad, verify=False)
    assert not isinstance(ei.value, VerifyError)
    assert "nosuch" in str(ei.value)


def test_verify_false_dataflow_matches_legacy():
    bad = {"routines": [{"blas": "axpy", "name": "a",
                         "scalars": {"alpha": {"input": "v"}},
                         "inputs": {"x": "v"}}]}
    with pytest.raises(SpecError, match="conflicting kinds") as ei:
        lowering.lower(bad, upto="infer", verify=False)
    assert not isinstance(ei.value, VerifyError)


def test_structured_fields_on_spec_error():
    with pytest.raises(SpecError) as ei:
        spec_mod.parse({"routines": [{"blas": "nope", "name": "n"}]})
    assert ei.value.code == "RV101"
    assert ei.value.path == "routines[0].blas"
    assert "available" in (ei.value.hint or "")


def test_executable_verify_reports():
    import repro.blas as blas
    exe = blas.compile({"routines": [{"blas": "dot", "name": "d"}]})
    report = exe.verify()
    assert report.ok and report.kind == "dataflow"


def test_compile_gate_rejects_broken_spec():
    import repro.blas as blas
    with pytest.raises(VerifyError):
        blas.compile({"routines": [{"blas": "dot", "name": "d",
                                    "connections": {"out": ["d.x"]}}]})


def test_report_json_round_trip():
    report = verify.analyze(_loop())
    doc = json.loads(report.to_json())
    assert doc["program"] == "mini"
    assert doc["kind"] == "loop"
    codes = {d["code"] for d in doc["diagnostics"]}
    assert "RV204" in codes


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_all_shipped_clean(capsys):
    from repro.verify.__main__ import main
    assert main(["--all-shipped", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] and len(doc["specs"]) >= 21


def test_cli_broken_fixture_fails(tmp_path, capsys):
    from repro.verify.__main__ import main
    p = tmp_path / "broken.json"
    p.write_text(json.dumps(
        {"routines": [{"blas": "nope", "name": "n"}]}))
    assert main([str(p), "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert not doc["ok"]
    assert doc["specs"][0]["diagnostics"][0]["code"] == "RV101"
    assert doc["specs"][0]["diagnostics"][0]["path"] == \
        "routines[0].blas"


def test_cli_repo_broken_fixture(capsys):
    # the same fixture the CI verify-smoke job runs against
    import pathlib

    from repro.verify.__main__ import main
    fixture = str(pathlib.Path(__file__).parent / "fixtures"
                  / "broken_spec.json")
    assert main([fixture, "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    codes = {d["code"] for s in doc["specs"]
             for d in s["diagnostics"]}
    assert {"RV201", "RV301", "RV203", "RV204"} <= codes
