"""Level-2 anchored fusion: gemv/symv anchors absorbing adjacent
level-1 routines into one streamed Pallas kernel.

Covers the tentpole invariants:
  * `symv -> dot` and `gemv -> axpy -> nrm2` lower to a SINGLE
    pallas_call in dataflow mode (counted, not inferred);
  * fused (dataflow) == unfused (nodataflow) == reference numerically;
  * convexity: fusing is rejected when it would create a path that
    leaves and re-enters the group;
  * the modeled HBM bytes for the CG iteration body drop >= 25% on
    the avoidable (vector) traffic — the number BENCH_fused_l2.json
    gates against.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Program
from repro.core.lowering import lower
from repro.kernels.common import pl

MODES = ("dataflow", "nodataflow", "reference")


def _vec(n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)


def _mat(m, n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, n),
                             jnp.float32)


def _sym(n, seed=0):
    a = _mat(n, n, seed)
    return (a + a.T) / 2


SYMV_DOT = {
    "name": "symv_dot",
    "routines": [
        {"blas": "symv", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "x", "y": "x"},
         "connections": {"out": "d.x"}},
        {"blas": "dot", "name": "d", "inputs": {"y": "x"},
         "outputs": {"out": "q"}},
    ],
}

GEMV_AXPY_NRM2 = {
    "name": "gemv_axpy_nrm2",
    "routines": [
        {"blas": "gemv", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "p", "y": "y0"},
         "connections": {"out": "up.x"}, "outputs": {"out": "q"}},
        {"blas": "axpy", "name": "up",
         "scalars": {"alpha": {"input": "neg_alpha"}},
         "inputs": {"y": "r"},
         "connections": {"out": "rn.x"}, "outputs": {"out": "r_next"}},
        {"blas": "nrm2", "name": "rn", "outputs": {"out": "rnorm"}},
    ],
}


class _PallasCallCounter:
    """Counts pl.pallas_call invocations (i.e. generated kernels
    actually launched/traced) during a block."""

    def __init__(self, monkeypatch):
        self.count = 0
        real = pl.pallas_call

        def counting(*args, **kwargs):
            self.count += 1
            return real(*args, **kwargs)

        monkeypatch.setattr(pl, "pallas_call", counting)


# ---------------------------------------------------------------------------
# Planner structure
# ---------------------------------------------------------------------------


def test_symv_dot_plans_one_anchored_group():
    ir = lower(SYMV_DOT, upto="fuse")
    assert len(ir.groups) == 1
    assert ir.groups[0].fused and ir.groups[0].anchor == "mv"


def test_gemv_chain_plans_one_anchored_group():
    ir = lower(GEMV_AXPY_NRM2, upto="fuse")
    assert len(ir.groups) == 1
    assert ir.groups[0].nodes == ["mv", "up", "rn"]
    assert ir.groups[0].anchor == "mv"


def test_nodataflow_mode_never_anchors():
    ir = lower(GEMV_AXPY_NRM2, mode="nodataflow", upto="fuse")
    assert len(ir.groups) == 3
    assert all(g.anchor is None and not g.fused for g in ir.groups)


def test_anchor_knob_disables_only_anchored_fusion():
    ir = lower(GEMV_AXPY_NRM2, anchor=False, upto="fuse")
    # gemv alone + the still-fused level-1 tail
    assert len(ir.groups) == 2
    assert ir.groups[0].nodes == ["mv"] and ir.groups[0].anchor is None
    assert ir.groups[1].nodes == ["up", "rn"] and ir.groups[1].fused


def test_anchor_without_fuse_rejected():
    with pytest.raises(ValueError, match="anchor=True requires"):
        lower(GEMV_AXPY_NRM2, fuse=False, anchor=True)
    with pytest.raises(ValueError, match="anchor=True requires"):
        lower(GEMV_AXPY_NRM2, mode="nodataflow", anchor=True)


# ---------------------------------------------------------------------------
# Kernel count: the chains launch exactly ONE pallas_call
# ---------------------------------------------------------------------------


def test_symv_dot_single_pallas_call(monkeypatch):
    prog = Program.from_spec(SYMV_DOT)
    n = 261
    a, x = _sym(n, 0), _vec(n, 1)
    counter = _PallasCallCounter(monkeypatch)
    out = prog(A=a, x=x)
    assert counter.count == 1
    want = x @ (np.asarray(a, np.float64) @ np.asarray(x, np.float64))
    np.testing.assert_allclose(out["q"], want, rtol=1e-4,
                               atol=1e-3 * max(1.0, abs(want)))


def test_gemv_axpy_nrm2_single_pallas_call(monkeypatch):
    prog = Program.from_spec(GEMV_AXPY_NRM2)
    m, n = 391, 133
    a, p, r = _mat(m, n, 2), _vec(n, 3), _vec(m, 4)
    y0 = jnp.zeros(m, jnp.float32)
    counter = _PallasCallCounter(monkeypatch)
    out = prog(A=a, p=p, y0=y0, r=r, neg_alpha=-0.3)
    assert counter.count == 1
    q = np.asarray(a, np.float64) @ np.asarray(p, np.float64)
    r_next = np.asarray(r, np.float64) - 0.3 * q
    np.testing.assert_allclose(out["q"], q, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(out["r_next"], r_next, rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(out["rnorm"], np.linalg.norm(r_next),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# Numerical equivalence across all three modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 300, 1000])
def test_symv_dot_mode_equivalence(n):
    a, x = _sym(n, 5), _vec(n, 6)
    outs = {m: Program.from_spec(SYMV_DOT, mode=m)(A=a, x=x)
            for m in MODES}
    ref = np.float64(outs["reference"]["q"])
    scale = max(1.0, abs(ref))
    for m in ("dataflow", "nodataflow"):
        np.testing.assert_allclose(np.float64(outs[m]["q"]), ref,
                                   rtol=1e-4, atol=1e-3 * scale)


@pytest.mark.parametrize("m,n", [(128, 128), (257, 96), (1000, 513)])
def test_gemv_axpy_nrm2_mode_equivalence(m, n):
    inputs = dict(A=_mat(m, n, 7), p=_vec(n, 8), r=_vec(m, 9),
                  y0=jnp.zeros(m, jnp.float32), neg_alpha=-0.7)
    outs = {md: Program.from_spec(GEMV_AXPY_NRM2, mode=md)(**inputs)
            for md in MODES}
    for name in ("q", "r_next", "rnorm"):
        ref = np.asarray(outs["reference"][name], np.float64)
        scale = max(1.0, float(np.abs(ref).max()))
        for md in ("dataflow", "nodataflow"):
            np.testing.assert_allclose(
                np.asarray(outs[md][name], np.float64), ref,
                rtol=1e-4, atol=1e-3 * scale)


def test_upstream_producer_absorbed_into_anchor():
    """scal -> symv.y: the producer runs in the row phase (j == 0)."""
    spec = {"routines": [
        {"blas": "scal", "name": "sc", "scalars": {"alpha": 2.0},
         "inputs": {"x": "w"}, "connections": {"out": "mv.y"}},
        {"blas": "symv", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 0.5},
         "inputs": {"A": "A", "x": "x"}, "outputs": {"out": "y2"}},
    ]}
    ir = lower(spec, upto="fuse")
    assert len(ir.groups) == 1 and ir.groups[0].anchor == "mv"
    n = 300
    a, x, w = _sym(n, 10), _vec(n, 11), _vec(n, 12)
    outs = {m: Program.from_spec(spec, mode=m)(A=a, x=x, w=w)
            for m in MODES}
    ref = np.asarray(outs["reference"]["y2"], np.float64)
    for m in ("dataflow", "nodataflow"):
        np.testing.assert_allclose(np.asarray(outs[m]["y2"], np.float64),
                                   ref, rtol=1e-4, atol=1e-3)


def test_anchored_index_reduction_consumer():
    """gemv -> iamax: the index-carrying reduction accumulates across
    row blocks of the anchored kernel."""
    spec = {"routines": [
        {"blas": "gemv", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "x", "y": "y0"},
         "connections": {"out": "am.x"}},
        {"blas": "iamax", "name": "am", "outputs": {"out": "idx"}},
    ]}
    ir = lower(spec, upto="fuse")
    assert len(ir.groups) == 1 and ir.groups[0].anchor == "mv"
    m, n = 700, 80
    a, x = _mat(m, n, 13), _vec(n, 14)
    prog = Program.from_spec(spec)
    out = prog(A=a, x=x, y0=jnp.zeros(m, jnp.float32))
    want = int(np.argmax(np.abs(np.asarray(a) @ np.asarray(x))))
    assert int(out["idx"]) == want


# ---------------------------------------------------------------------------
# Convexity
# ---------------------------------------------------------------------------


def test_convexity_rejects_reentrant_absorption():
    """gemv1 feeds both gemv2 and an axpy that ALSO consumes gemv2's
    output: absorbing the axpy into gemv1's group would put gemv2 on
    a path that leaves and re-enters the group, so the planner must
    leave gemv1 alone and let gemv2 take the axpy instead."""
    spec = {"routines": [
        {"blas": "gemv", "name": "mv1",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "x", "y": "x"},
         "connections": {"out": ["mv2.x", "up.x"]}},
        {"blas": "gemv", "name": "mv2",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "B", "y": "x"},
         "connections": {"out": "up.y"}},
        {"blas": "axpy", "name": "up", "scalars": {"alpha": 2.0},
         "outputs": {"out": "z"}},
    ]}
    ir = lower(spec, upto="fuse")
    by_nodes = {tuple(g.nodes): g for g in ir.groups}
    assert (("mv1",) in by_nodes), ir.groups
    assert by_nodes[("mv1",)].anchor is None
    assert (("mv2", "up") in by_nodes), ir.groups
    assert by_nodes[("mv2", "up")].anchor == "mv2"
    # and the split program still computes the right thing
    n = 192
    a, b_, x = _sym(n, 15), _sym(n, 16), _vec(n, 17)
    outs = {m: Program.from_spec(spec, mode=m)(A=a, B=b_, x=x)
            for m in MODES}
    ref = np.asarray(outs["reference"]["z"], np.float64)
    scale = max(1.0, float(np.abs(ref).max()))
    for m in ("dataflow", "nodataflow"):
        np.testing.assert_allclose(np.asarray(outs[m]["z"], np.float64),
                                   ref, rtol=1e-4, atol=1e-3 * scale)


def test_level1_convexity_still_rejected():
    """The incremental convexity check must still split a level-1 pair
    whose only joining path runs through a non-absorbable middle node
    (here: through a gemv's column operand, which is never fused)."""
    spec = {"routines": [
        {"blas": "scal", "name": "e1", "scalars": {"alpha": 3.0},
         "inputs": {"x": "x"},
         "connections": {"out": ["mv.x", "e2.x"]}},
        {"blas": "gemv", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "y": "x"},
         "connections": {"out": "e2.y"}},
        {"blas": "axpy", "name": "e2", "scalars": {"alpha": 1.0},
         "outputs": {"out": "z"}},
    ]}
    ir = lower(spec, upto="fuse")
    by_nodes = {tuple(g.nodes): g for g in ir.groups}
    assert ("e1",) in by_nodes, ir.groups       # e1+e2 would re-enter
    assert ("mv", "e2") in by_nodes, ir.groups  # the anchor takes e2
    n = 128
    a, x = _sym(n, 18), _vec(n, 19)
    outs = {m: Program.from_spec(spec, mode=m)(A=a, x=x)
            for m in MODES}
    ref = np.asarray(outs["reference"]["z"], np.float64)
    scale = max(1.0, float(np.abs(ref).max()))
    for m in ("dataflow", "nodataflow"):
        np.testing.assert_allclose(np.asarray(outs[m]["z"], np.float64),
                                   ref, rtol=1e-4, atol=1e-3 * scale)


def test_anchored_group_ordered_after_outside_producer():
    """Two independent anchors feeding one dot: the anchored group
    {mv1, d} must execute AFTER mv2, whose output drives d's other
    port — group order is a topo sort of the group quotient, not
    first-member topo index."""
    spec = {"routines": [
        {"blas": "gemv", "name": "mv1",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "x", "y": "x"},
         "connections": {"out": "d.x"}},
        {"blas": "gemv", "name": "mv2",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "B", "x": "x", "y": "x"},
         "connections": {"out": "d.y"}},
        {"blas": "dot", "name": "d", "outputs": {"out": "s"}},
    ]}
    ir = lower(spec, upto="fuse")
    order = [tuple(g.nodes) for g in ir.groups]
    assert order.index(("mv2",)) < order.index(("mv1", "d")), order
    n = 160
    a, b_, x = _sym(n, 24), _sym(n, 25), _vec(n, 26)
    outs = {m: Program.from_spec(spec, mode=m)(A=a, B=b_, x=x)
            for m in MODES}
    ref = np.float64(outs["reference"]["s"])
    scale = max(1.0, abs(ref))
    for m in ("dataflow", "nodataflow"):
        np.testing.assert_allclose(np.float64(outs[m]["s"]), ref,
                                   rtol=1e-4, atol=1e-3 * scale)


def test_cross_group_fanout_schedules_acyclically():
    """e fans out into the anchored group (d.y) AND into a second
    anchor outside it (mv2.y). The planner absorbs the level-1 pair
    {e, d} into mv1's group (legal: e is a sibling emitted in the
    finish phase, its output still written for mv2) and the group
    quotient must stay an executable DAG — mv2 runs after the
    anchored group that produces both its operands."""
    spec = {"routines": [
        {"blas": "gemv", "name": "mv1",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "x": "x", "y": "x"},
         "connections": {"out": ["d.x", "mv2.x"]}},
        {"blas": "scal", "name": "e", "scalars": {"alpha": 2.0},
         "inputs": {"x": "w"},
         "connections": {"out": ["mv2.y", "d.y"]}},
        {"blas": "gemv", "name": "mv2",
         "scalars": {"alpha": 1.0, "beta": 0.5},
         "inputs": {"A": "B"}, "outputs": {"out": "v"}},
        {"blas": "dot", "name": "d", "outputs": {"out": "s"}},
    ]}
    ir = lower(spec, upto="fuse")
    order = [tuple(g.nodes) for g in ir.groups]
    assert order == [("e", "mv1", "d"), ("mv2",)], ir.groups
    assert ir.groups[0].anchor == "mv1"
    n = 140
    a, b_ = _sym(n, 27), _sym(n, 28)
    x, w = _vec(n, 29), _vec(n, 30)
    outs = {m: Program.from_spec(spec, mode=m)(A=a, B=b_, x=x, w=w)
            for m in MODES}
    for name in ("s", "v"):
        ref = np.asarray(outs["reference"][name], np.float64)
        scale = max(1.0, float(np.abs(ref).max()))
        for m in ("dataflow", "nodataflow"):
            np.testing.assert_allclose(
                np.asarray(outs[m][name], np.float64), ref,
                rtol=1e-4, atol=1e-3 * scale)


# ---------------------------------------------------------------------------
# Solver bodies + cost model
# ---------------------------------------------------------------------------


def test_cg_matvec_body_single_kernel(monkeypatch):
    """The CG body's q = A p ; pq = p.q stage is one anchored kernel
    in dataflow mode."""
    from repro.solvers import specs
    prog = Program.from_spec(specs.CG_MATVEC)
    n = 173
    a, p = _sym(n, 20), _vec(n, 21)
    counter = _PallasCallCounter(monkeypatch)
    out = prog(A=a, p=p)
    assert counter.count == 1
    q = np.asarray(a, np.float64) @ np.asarray(p, np.float64)
    np.testing.assert_allclose(out["q"], q, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(out["pq"], np.asarray(p, np.float64) @ q,
                               rtol=1e-4, atol=1e-2)


def test_cg_body_vector_traffic_reduction_meets_gate():
    """The acceptance number: modeled HBM bytes for the CG iteration
    body drop >= 25% on the avoidable vector traffic vs unfused (the
    matrix stream is schedule-invariant and identical in both)."""
    import repro.blas as blas
    from repro.solvers import specs
    shapes = {"A": (1024, 1024), "b": 1024, "x0": 1024}
    fused = blas.compile(specs.CG_LOOP).cost_report(shapes)
    unfused = blas.compile(specs.CG_LOOP,
                           mode="nodataflow").cost_report(shapes)
    assert fused.bytes < unfused.bytes
    assert fused.matrix_bytes == unfused.matrix_bytes
    assert fused.vector_bytes < unfused.vector_bytes
    assert fused.vector_reduction >= 0.25
    # the physical view is strictly smaller: q and r' are still
    # written once because later loop stages consume them
    assert 0 < fused.fused_savings_exact < fused.fused_savings
    assert fused.bytes_exact > fused.bytes
    assert fused.vector_reduction_exact < fused.vector_reduction
    # loop solvers converge identically with the anchored bodies
    n = 128
    k = jax.random.PRNGKey(22)
    mm = jax.random.normal(k, (n, n), jnp.float32)
    a = mm @ mm.T / n + jnp.eye(n)
    b_ = _vec(n, 23)
    from repro.solvers import LoopProgram
    res = LoopProgram(specs.CG_LOOP, max_iters=300).solve(
        A=a, b=b_, x0=jnp.zeros(n, jnp.float32), tol=1e-6)
    assert bool(res.converged)
    np.testing.assert_allclose(
        res.x, np.linalg.solve(np.asarray(a, np.float64),
                               np.asarray(b_, np.float64)),
        rtol=1e-3, atol=1e-3)
