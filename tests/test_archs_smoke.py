"""Per-architecture smoke tests: a REDUCED config of the same family
runs one forward + one train-grad step + a prefill/decode consistency
check on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import (decode_step, forward_logits, init_cache,
                          init_params, prefill, train_loss)

B, S = 2, 24


def _batch(cfg, key):
    ki, kl = jax.random.split(key)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(ki, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(ki, (B, S, cfg.d_model),
                                   dtype=jnp.float32)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    # f32 params on CPU for numerics
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = forward_logits(params, cfg, batch["inputs"], remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.value_and_grad(train_loss)(params, cfg, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    """decode_step after prefill(S-1 tokens) must reproduce the
    full-sequence forward logits at the last position."""
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    inputs = batch["inputs"]
    max_len = S + 4

    full = forward_logits(params, cfg, inputs, remat=False)

    # prefill on the first S-1 tokens, then decode token S-1
    _, caches, pos = prefill(params, cfg, inputs[:, :S - 1], max_len)
    assert int(pos) == S - 1
    last_in = inputs[:, S - 1]
    logits, caches = decode_step(params, cfg, last_in, caches, pos)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full[:, -1], np.float32), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["llama3-8b", "hymba-1.5b",
                                  "minicpm3-4b", "h2o-danube-3-4b"])
def test_pure_decode_chain(arch):
    """init_cache + N decode steps == forward over those N tokens."""
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(4))
    n = 10
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, n), 0,
                              cfg.vocab_size)
    full = forward_logits(params, cfg, toks, remat=False)
    caches = init_cache(cfg, B, n + 2, dtype=jnp.float32)
    outs = []
    for t in range(n):
        logits, caches = decode_step(params, cfg, toks[:, t], caches,
                                     jnp.asarray(t, jnp.int32))
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_n_params_accounting():
    """n_params() approximation within 20% of the actual leaf count for
    a dense arch (sanity for the roofline's 6ND)."""
    cfg = get_config("llama3-8b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(x.size for x in jax.tree.leaves(params))
    approx = cfg.n_params()
    assert 0.5 * actual < approx < 2.0 * actual, (actual, approx)
