"""Correctness of the §Perf optimization paths: banded SWA attention,
DP-grouped MoE dispatch (semantics must match the baselines)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import chunked_attention
from repro.models.layers import init_dense
from repro.models.moe import moe_ffn, moe_ffn_reference


@pytest.mark.parametrize("window,s", [(16, 192), (32, 192), (50, 256)])
def test_banded_swa_matches_oracle(window, s):
    key = jax.random.PRNGKey(0)
    b, hq, hkv, d = 2, 4, 2, 16
    q = jax.random.normal(key, (b, hq, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, s, d))
    got = chunked_attention(q, k, v, causal=True, window=window,
                            block_q=32, block_k=32, remat=False)
    want = ref.mha(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_banded_swa_grads_finite():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 128, 8))

    def loss(q):
        o = chunked_attention(q, q, q, causal=True, window=16,
                              block_q=32, remat=True)
        return jnp.sum(o ** 2)

    g = jax.grad(loss)(q)
    assert np.isfinite(np.asarray(g)).all()


def test_moe_grouped_dispatch_matches_reference():
    d, de, e, k, t, groups = 32, 16, 4, 2, 128, 4
    keys = iter(jax.random.split(jax.random.PRNGKey(4), 6))
    p = {"router": init_dense(next(keys), (d, e)),
         "we_gate": init_dense(next(keys), (e, d, de)),
         "we_up": init_dense(next(keys), (e, d, de)),
         "we_down": init_dense(next(keys), (e, de, d))}
    x = jax.random.normal(next(keys), (t, d))
    got = moe_ffn(p, x, n_experts=e, top_k=k, capacity_factor=8.0,
                  groups=groups)
    want = moe_ffn_reference(p, x, n_experts=e, top_k=k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Observability off-switch: disabled instrumentation must cost nothing
# ---------------------------------------------------------------------------


def test_obs_disabled_adds_no_records_and_no_retrace():
    """With `repro.obs` disabled (the default), the instrumented
    compile/solve paths must leave zero records behind and must not
    change jit retrace behaviour: the loop body still traces once and
    repeated solves reuse the compiled loop."""
    from repro import blas, obs
    from repro.solvers import specs

    assert not obs.enabled()
    n = 16
    A = jnp.eye(n, dtype=jnp.float32) * 2.0
    b = jnp.ones(n, jnp.float32)
    ops = {"A": A, "b": b, "x0": jnp.zeros(n, jnp.float32)}

    exe = blas.compile(specs.CG_LOOP, max_iters=4)
    exe.run(tol=0.0, **ops)
    exe.run(tol=0.0, **ops)
    assert exe.trace_count == 1          # no retrace from span guards
    assert obs.records() == []           # nothing recorded
    assert obs.counters() == {}
    # the disabled span is the shared null object — no per-call cost
    assert obs.span("kernel.group") is obs.NULL_SPAN
