"""Level-3 anchored fusion: gemm anchors with 2-D (bm, bn) output
tiles, the gemvt anchored tier, and block-CG riding the machinery.

Covers the tentpole invariants:
  * gemm is a legal anchor: a gemm -> tile-eltwise -> column-reduction
    chain plans as ONE anchored group and launches a SINGLE
    pallas_call in dataflow mode (counted, not inferred);
  * fused (dataflow) == unfused (nodataflow) == reference numerically
    for gemm-anchored groups, including epilogues with their own
    public matrix operands;
  * gemvt gets its own anchored tier;
  * lowering the block-CG stage programs emits `codegen.group` events
    whose anchored group carries the gemm anchor (the acceptance
    criterion for BLOCK_CG_LOOP's fused body);
  * the cost model does not double-count matrix streams for anchored
    gemm groups (hand-computed byte regression);
  * `blas.block_cg` matches per-column `np.linalg.solve`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.blas as blas
from repro import obs
from repro.core import Program, lowering
from repro.core.lowering import lower
from repro.kernels.common import pl
from repro.solvers import specs

MODES = ("dataflow", "nodataflow", "reference")


def _mat(m, n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, n),
                             jnp.float32)


def _vec(n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)


def _spd(n, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n)).astype(np.float32)
    return jnp.asarray(m @ m.T + n * np.eye(n, dtype=np.float32))


# gemm anchor -> per-column axpy epilogue (with its OWN public matrix
# operand) -> column-dot reduction: the canonical level-3 shape
GEMM_COLAXPY_COLDOT = {
    "name": "gemm_colaxpy_coldot",
    "routines": [
        {"blas": "gemm", "name": "mm",
         "scalars": {"alpha": 1.0, "beta": 0.0},
         "inputs": {"A": "A", "B": "B", "C": "C0"},
         "connections": {"out": "up.x"}, "outputs": {"out": "Q"}},
        {"blas": "colaxpy", "name": "up",
         "inputs": {"a": "alphas", "y": "Y0"},
         "connections": {"out": ["cd.x", "cd.y"]},
         "outputs": {"out": "R"}},
        {"blas": "coldot", "name": "cd", "outputs": {"out": "rz"}},
    ],
}

# gemvt (x rides the ROWS: out = alpha A^T x + beta y) -> scal -> nrm2
GEMVT_SCAL_NRM2 = {
    "name": "gemvt_scal_nrm2",
    "routines": [
        {"blas": "gemvt", "name": "mv",
         "scalars": {"alpha": 1.0, "beta": 1.0},
         "inputs": {"A": "A", "x": "x", "y": "y0"},
         "connections": {"out": "sc.x"}, "outputs": {"out": "q"}},
        {"blas": "scal", "name": "sc", "scalars": {"alpha": -0.5},
         "connections": {"out": "nn.x"}, "outputs": {"out": "w"}},
        {"blas": "nrm2", "name": "nn", "outputs": {"out": "wnorm"}},
    ],
}


class _PallasCallCounter:
    """Counts pl.pallas_call invocations (generated kernels actually
    launched/traced) during a block."""

    def __init__(self, monkeypatch):
        self.count = 0
        real = pl.pallas_call

        def counting(*args, **kwargs):
            self.count += 1
            return real(*args, **kwargs)

        monkeypatch.setattr(pl, "pallas_call", counting)


def _gemm_chain_inputs(m, k, s, seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "A": jax.random.normal(key, (m, k), jnp.float32),
        "B": jax.random.normal(jax.random.fold_in(key, 1), (k, s),
                               jnp.float32),
        "C0": jnp.zeros((m, s), jnp.float32),
        "Y0": jax.random.normal(jax.random.fold_in(key, 2), (m, s),
                                jnp.float32),
        "alphas": jax.random.normal(jax.random.fold_in(key, 3), (s,),
                                    jnp.float32),
    }


# ---------------------------------------------------------------------------
# Planner structure
# ---------------------------------------------------------------------------


def test_gemm_chain_plans_one_anchored_group():
    ir = lower(GEMM_COLAXPY_COLDOT, upto="fuse")
    assert len(ir.groups) == 1
    g = ir.groups[0]
    assert g.fused and g.anchor == "mm"
    assert g.nodes == ["mm", "up", "cd"]
    assert ir.graph.nodes["mm"].rdef.name == "gemm"


def test_gemvt_chain_plans_one_anchored_group():
    ir = lower(GEMVT_SCAL_NRM2, upto="fuse")
    assert len(ir.groups) == 1
    assert ir.groups[0].nodes == ["mv", "sc", "nn"]
    assert ir.groups[0].anchor == "mv"


def test_block_cg_stage_programs_plan_gemm_anchors():
    """The block-CG body's matvec and the residual both fuse around
    their gemm; the column-dot epilogue rides inside the tile group."""
    for spec, anchor, members in (
            (specs.BLOCK_CG_MATVEC, "mv", ["mv", "pq"]),
            (specs.BLOCK_RESIDUAL, "resid", ["resid", "rz"])):
        ir = lower(spec, upto="fuse")
        by_nodes = {tuple(g.nodes): g for g in ir.groups}
        assert tuple(members) in by_nodes, ir.groups
        g = by_nodes[tuple(members)]
        assert g.fused and g.anchor == anchor
        assert ir.graph.nodes[anchor].rdef.name == "gemm"


def test_nodataflow_mode_never_anchors_gemm():
    ir = lower(GEMM_COLAXPY_COLDOT, mode="nodataflow", upto="fuse")
    assert len(ir.groups) == 3
    assert all(g.anchor is None and not g.fused for g in ir.groups)


# ---------------------------------------------------------------------------
# Kernel count: the gemm-anchored chain launches exactly ONE pallas_call
# ---------------------------------------------------------------------------


def test_gemm_chain_single_pallas_call(monkeypatch):
    prog = Program.from_spec(GEMM_COLAXPY_COLDOT)
    m, k, s = 300, 190, 6
    inputs = _gemm_chain_inputs(m, k, s, seed=4)
    counter = _PallasCallCounter(monkeypatch)
    out = prog(**inputs)
    assert counter.count == 1
    q = np.asarray(inputs["A"], np.float64) @ \
        np.asarray(inputs["B"], np.float64)
    r = np.asarray(inputs["Y0"], np.float64) \
        + q * np.asarray(inputs["alphas"], np.float64)
    np.testing.assert_allclose(out["Q"], q, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(out["R"], r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(out["rz"], np.sum(r * r, axis=0),
                               rtol=1e-3, atol=1e-2)


def test_block_cg_matvec_single_kernel(monkeypatch):
    """q = A P ; pq = diag(P^T Q): one anchored tile kernel in
    dataflow mode, even though P feeds both the gemm and the
    column-dot (the duplicate stream reads once)."""
    prog = Program.from_spec(specs.BLOCK_CG_MATVEC)
    n, s = 170, 5
    a, p = _spd(n, 6), _mat(n, s, 7)
    counter = _PallasCallCounter(monkeypatch)
    out = prog(A=a, P=p)
    assert counter.count == 1
    q = np.asarray(a, np.float64) @ np.asarray(p, np.float64)
    np.testing.assert_allclose(out["q"], q, rtol=1e-4,
                               atol=1e-2 * max(1.0, np.abs(q).max()))
    np.testing.assert_allclose(
        out["pq"], np.sum(np.asarray(p, np.float64) * q, axis=0),
        rtol=1e-3, atol=1e-2 * max(1.0, np.abs(q).max()))


# ---------------------------------------------------------------------------
# Numerical equivalence across all three modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,s", [(64, 64, 4), (257, 96, 3),
                                   (513, 300, 8)])
def test_gemm_chain_mode_equivalence(m, k, s):
    inputs = _gemm_chain_inputs(m, k, s, seed=8)
    outs = {md: Program.from_spec(GEMM_COLAXPY_COLDOT, mode=md)(**inputs)
            for md in MODES}
    for name in ("Q", "R", "rz"):
        ref = np.asarray(outs["reference"][name], np.float64)
        scale = max(1.0, float(np.abs(ref).max()))
        for md in ("dataflow", "nodataflow"):
            np.testing.assert_allclose(
                np.asarray(outs[md][name], np.float64), ref,
                rtol=1e-4, atol=1e-3 * scale)


@pytest.mark.parametrize("m,n", [(128, 128), (391, 133)])
def test_gemvt_chain_mode_equivalence(m, n):
    inputs = dict(A=_mat(m, n, 9), x=_vec(m, 10), y0=_vec(n, 11))
    outs = {md: Program.from_spec(GEMVT_SCAL_NRM2, mode=md)(**inputs)
            for md in MODES}
    for name in ("q", "w", "wnorm"):
        ref = np.asarray(outs["reference"][name], np.float64)
        scale = max(1.0, float(np.abs(ref).max()))
        for md in ("dataflow", "nodataflow"):
            np.testing.assert_allclose(
                np.asarray(outs[md][name], np.float64), ref,
                rtol=1e-4, atol=1e-3 * scale)


# ---------------------------------------------------------------------------
# Acceptance: block-CG lowers with a gemm-anchored fused body
# ---------------------------------------------------------------------------


def test_block_cg_loop_emits_gemm_anchored_group_event():
    """Compiling BLOCK_CG_LOOP must produce at least one
    codegen.group event whose anchored group is anchored on a gemm
    routine — the level-3 acceptance criterion."""
    lowering.clear_cache()   # events fire on lowering-cache misses
    with obs.capture() as reg:
        blas.compile(specs.BLOCK_CG_LOOP, max_iters=4)
        events = [r for r in reg.records
                  if r["kind"] == "event"
                  and r["name"] == "codegen.group"]
    anchored = [e for e in events if e["attrs"]["kind"] == "anchored"]
    assert anchored, events
    gemm_anchored = [
        e for e in anchored
        if e["attrs"]["program"] in ("block_cg_matvec",
                                     "block_residual")
        and e["attrs"]["anchor"] in ("mv", "resid")]
    assert gemm_anchored, anchored


# ---------------------------------------------------------------------------
# Cost model: no double-counted matrix streams in 2-D anchored groups
# ---------------------------------------------------------------------------


def test_block_cg_matvec_cost_model_hand_computed():
    """Byte regression for the anchored gemm group, hand-computed.

    Naive (per call, f32):
      gemm  A(n,n) + B(n,s) + C(n,s) + out(n,s)  = (n^2 + 3ns) * 4
      coldot x(n,s) + y(n,s) + out(s)            = (2ns + s) * 4
    Fused group {mv, pq}: the internal q edge keeps its write+read
    on-chip (2ns*4) and pq's two panel reads collapse onto streams
    already in the tile (x=P duplicates the gemm's B stream, y=q is
    internal), so
      fused_savings       = 4ns * 4   (round-trip convention)
      fused_savings_exact = 3ns * 4   (q is public: its write still
                                       issues once)
      matrix_bytes        = (n^2 + 2ns) * 4   (A + B/C shared panel
                            streams; no double count of P)
    """
    n, s = 256, 8
    rep = blas.compile(specs.BLOCK_CG_MATVEC).cost_report(
        {"A": (n, n), "P": (n, s)})
    f = 4
    assert rep.bytes_naive == (n * n + 3 * n * s) * f \
        + (2 * n * s + s) * f
    assert rep.fused_savings == 4 * n * s * f
    assert rep.fused_savings_exact == 3 * n * s * f
    assert rep.matrix_bytes == (n * n + 2 * n * s) * f
    assert rep.bytes == rep.bytes_naive - rep.fused_savings
    # the unfused schedule has no savings and the same matrix pool
    # EXCEPT the duplicate-panel credit (it really streams P twice)
    unf = blas.compile(specs.BLOCK_CG_MATVEC,
                       mode="nodataflow").cost_report(
        {"A": (n, n), "P": (n, s)})
    assert unf.fused_savings == 0
    assert unf.bytes == rep.bytes_naive
    assert unf.matrix_bytes == (n * n + 2 * n * s) * f \
        + rep.fused_savings_exact


def test_block_cg_body_bytes_beat_vmapped_cg():
    """The level-3 story in one assertion: per iteration, block-CG
    streams the matrix once; s vmapped CG lanes stream it s times."""
    n, s = 512, 8
    block = blas.compile(specs.BLOCK_CG_LOOP).cost_report(
        {"A": (n, n), "B": (n, s), "x0": (n, s)})
    cg = blas.compile(specs.CG_LOOP).cost_report(
        {"A": (n, n), "b": n, "x0": n})
    assert block.bytes < cg.bytes * s
    assert block.matrix_bytes < cg.matrix_bytes * s


# ---------------------------------------------------------------------------
# block-CG end to end
# ---------------------------------------------------------------------------


def test_block_cg_matches_dense_solve_per_column():
    n, s = 48, 3
    a = _spd(n, 12)
    B = _mat(n, s, 13)
    res = blas.block_cg(a, B, tol=1e-8)
    assert res.x.shape == (n, s)
    assert bool(res.converged)
    want = np.linalg.solve(np.asarray(a, np.float64),
                           np.asarray(B, np.float64))
    np.testing.assert_allclose(np.asarray(res.x), want,
                               rtol=1e-3, atol=1e-3)


def test_block_cg_iterates_match_vmapped_cg():
    """Block-CG is s independent CG recurrences sharing one matvec:
    after a FIXED iteration budget the panel columns must equal the
    per-column vmapped CG iterates, not just the converged limits."""
    n, s, iters = 40, 4, 6
    a = _spd(n, 14)
    B = _mat(n, s, 15)
    eb = blas.compile(specs.BLOCK_CG_LOOP, max_iters=iters)
    ec = blas.compile(specs.CG_LOOP, max_iters=iters)
    rb = eb.run(A=a, B=B, x0=jnp.zeros_like(B), tol=0.0)
    rc = ec.batched(A=a, b=jnp.transpose(B),
                    x0=jnp.zeros((s, n), jnp.float32), tol=0.0)
    assert int(rb.iterations) == iters
    np.testing.assert_allclose(np.asarray(rb.x),
                               np.asarray(rc.x).T,
                               rtol=1e-4, atol=1e-5)
