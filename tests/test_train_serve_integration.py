"""Integration: the real train loop (loss drops, checkpoint restart
resumes) and the serving engine, on reduced configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop
from repro.models import init_params
from repro.serve import ServeEngine, pad_and_batch


def _tiny(arch="llama3-8b"):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, dtype="float32")


@pytest.mark.slow
def test_train_loss_decreases_and_restart_resumes(tmp_path):
    cfg = _tiny()
    mesh = make_host_mesh()
    stream = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                         batch_size=8, seed=0, branching=2)
    res1 = train_loop(cfg, mesh=mesh, steps=60, batch_size=8,
                      seq_len=32, ckpt_dir=tmp_path, ckpt_every=30,
                      lr=3e-3, remat=False, log_every=5,
                      stream=stream)
    first_loss = res1.losses[0][1]
    assert res1.final_loss < first_loss - 0.3, res1.losses
    # restart: picks up from step 60 checkpoint, runs 20 more
    res2 = train_loop(cfg, mesh=mesh, steps=80, batch_size=8,
                      seq_len=32, ckpt_dir=tmp_path, ckpt_every=40,
                      lr=3e-3, remat=False, log_every=5,
                      stream=stream)
    assert res2.restored_from == 60
    assert res2.steps_run == 20
    assert res2.final_loss < first_loss


def test_serve_engine_greedy_matches_decode_math():
    cfg = _tiny("h2o-danube-3-4b")   # exercises the SWA ring cache
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=48, batch_size=2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    res = engine.generate(prompts, max_new_tokens=6)
    assert len(res.tokens) == 2 and len(res.tokens[0]) == 6
    # greedy decoding is deterministic
    res2 = ServeEngine(cfg, params, max_len=48, batch_size=2).generate(
        prompts, max_new_tokens=6)
    assert res.tokens == res2.tokens
    # valid=1 marks row 1 as batch filler: same decode, row dropped
    res3 = ServeEngine(cfg, params, max_len=48, batch_size=2).generate(
        prompts, max_new_tokens=6, valid=1)
    assert len(res3.tokens) == 1
    assert res3.tokens[0] == res.tokens[0]


def test_pad_and_batch():
    batches = pad_and_batch([[1, 2], [3, 4, 5], [6]], batch_size=2,
                            pad_id=0)
    assert len(batches) == 2
    (full, full_valid), (short, short_valid) = batches
    assert full.shape == (2, 3)
    assert full_valid == 2
    np.testing.assert_array_equal(np.asarray(full[0]), [0, 1, 2])
    # the short final chunk fills with a repeat of its last request,
    # and the valid count is how callers tell the filler apart
    assert short.shape == (2, 1)
    assert short_valid == 1
    np.testing.assert_array_equal(np.asarray(short), [[6], [6]])


def test_placement_hints_applied():
    """AIEBLAS placement hints -> NamedShardings on program inputs."""
    from repro.core import Program
    from repro.core.placement import placement_shardings
    spec = {"routines": [
        {"blas": "axpy", "name": "a",
         "inputs": {"x": "x", "y": "y"},
         "placement": {"x": ["data"], "y": ["data"]}}]}
    prog = Program.from_spec(spec)
    mesh = make_host_mesh()
    sh = placement_shardings(prog.graph, mesh)
    assert set(sh) == {"x", "y"}
    assert sh["x"].spec == jax.sharding.PartitionSpec("data")
