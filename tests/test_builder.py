"""ProgramBuilder: digest-lossless spec round-trips for every shipped
spec, fluent construction (dataflow AND loop), and builder-misuse
error messages."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import blas
from repro.core import lowering, runtime, spec as spec_mod
from repro.solvers import specs

# every shipped spec: the runtime's canned programs plus every
# UPPER_CASE spec dict in solvers.specs (dataflow bodies + loop specs)
SHIPPED = {
    "AXPYDOT_SPEC": runtime.AXPYDOT_SPEC,
    "AXPY_SPEC": runtime.AXPY_SPEC,
    "GEMV_SPEC": runtime.GEMV_SPEC,
}
SHIPPED.update({n: getattr(specs, n) for n in dir(specs)
                if n.isupper() and isinstance(getattr(specs, n), dict)})


# ---------------------------------------------------------------------------
# Round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SHIPPED))
def test_roundtrip_digest_identical(name):
    raw = SHIPPED[name]
    rt = blas.ProgramBuilder.from_spec(raw).to_spec()
    assert lowering.spec_digest(rt) == lowering.spec_digest(raw)


@pytest.mark.parametrize("name", sorted(SHIPPED))
def test_double_roundtrip_stable(name):
    raw = SHIPPED[name]
    once = blas.ProgramBuilder.from_spec(raw).to_spec()
    twice = blas.ProgramBuilder.from_spec(once).to_spec()
    assert lowering.spec_digest(twice) == lowering.spec_digest(raw)


def test_roundtrip_does_not_alias_the_original():
    b = blas.ProgramBuilder.from_spec(specs.CG_UPDATE)
    rt = b.to_spec()
    rt["routines"][0]["name"] = "mutated"
    assert specs.CG_UPDATE["routines"][0]["name"] == "xup"
    assert b.to_spec()["routines"][0]["name"] == "xup"


def test_unparse_reparse_fixpoint():
    """spec.unparse is parse's inverse up to canonicalization: the
    canonical form re-parses to an identical canonical form."""
    for raw in (runtime.AXPYDOT_SPEC, specs.BICG_XRUPDATE,
                specs.RESIDUAL):
        ps = spec_mod.parse(raw)
        canon = spec_mod.unparse(ps)
        assert spec_mod.unparse(spec_mod.parse(canon)) == canon


def test_unparse_loop_reparse_fixpoint():
    for raw in (specs.CG_LOOP, specs.JACOBI_LOOP,
                specs.BICGSTAB_LOOP, specs.GMRES_LOOP):
        ls = spec_mod.parse_loop(raw)
        canon = spec_mod.unparse_loop(ls)
        assert spec_mod.unparse_loop(spec_mod.parse_loop(canon)) == canon


def test_from_spec_accepts_parsed_specs():
    ps = spec_mod.parse(specs.CG_MATVEC)
    b = blas.ProgramBuilder.from_spec(ps)
    exe = blas.compile(b)
    assert sorted(exe.output_names) == ["pq", "q"]
    ls = spec_mod.parse_loop(specs.CG_LOOP)
    bl = blas.ProgramBuilder.from_spec(ls)
    assert bl.is_loop
    assert spec_mod.is_loop_spec(bl.to_spec())


# ---------------------------------------------------------------------------
# Fluent dataflow construction
# ---------------------------------------------------------------------------


def test_fluent_axpydot_matches_canned_program():
    b = blas.program("axpydot")
    z = b.axpy(name="zcalc", alpha=b.input("neg_alpha"), x="v", y="w")
    b.dot(name="zdot", x=z, y="u", out="beta")
    exe = blas.compile(b)

    n = 512
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    w, v, u = (jax.random.normal(k, (n,), jnp.float32)
               for k in (k1, k2, k3))
    got = exe.one(neg_alpha=-0.7, v=v, w=w, u=u)
    want = runtime.axpydot_program()(neg_alpha=-0.7, v=v, w=w,
                                     u=u)["beta"]
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # same routine names + wiring -> same fusion plan
    assert [g.nodes for g in exe._impl.groups] == [["zcalc", "zdot"]]


def test_fluent_fanout_builds_connection_list():
    b = blas.program("fan")
    t = b.gemv(name="mv", alpha=1.0, beta=0.0, A="A", x="s", y="s")
    b.dot(name="tt", x=t, y=t)
    b.dot(name="ts", x=t, y="s")
    raw = b.to_spec()
    conns = raw["routines"][0]["connections"]["out"]
    assert conns == ["tt.x", "tt.y", "ts.x"]
    exe = blas.compile(b)
    # mv.out is consumed on-chip and unaliased, so it is not public
    assert sorted(exe.output_names) == ["ts.out", "tt.out"]


def test_fluent_scalar_literal_and_multi_output():
    b = blas.program("rots")
    outs = b.rot(c=0.6, s=0.8, x="x", y="y",
                 out={"out_x": "xr", "out_y": "yr"})
    assert sorted(outs) == ["out_x", "out_y"]
    exe = blas.compile(b)
    x = jnp.arange(8.0)
    y = jnp.ones(8)
    res = exe.run(x=x, y=y)
    np.testing.assert_allclose(res["xr"], 0.6 * x + 0.8 * y, rtol=1e-6)
    np.testing.assert_allclose(res["yr"], 0.6 * y - 0.8 * x, rtol=1e-6)


def test_fluent_loop_program_runs():
    b = blas.program("jac", dtype="float32")
    b.operand("A", "matrix").operand("b", "vector")
    b.operand("x0", "vector").operand("dinv", "vector")
    b.operand("omega", "scalar")
    b.setup(specs.NRM2, inputs={"x": "b"}, outputs={"norm": "bnorm"})
    b.setup(specs.RESIDUAL, inputs={"x": "x0"},
            outputs={"r": "r0", "rnorm": "rnorm0"})
    b.iterate(
        state={"x": "x0", "r": "r0"},
        body=[blas.stage(specs.JACOBI_UPDATE),
              blas.stage(specs.RESIDUAL, inputs={"x": "x_next"},
                         outputs={"r": "r_next", "rnorm": "rnorm"})],
        feedback={"x": "x_next", "r": "r_next"},
        stop={"metric": "rnorm", "init": "rnorm0", "scale": "bnorm",
              "rtol": 1e-6, "max_iters": 1000},
        guards={"nonfinite": ["x_next"],
                "divergence": {"factor": 1e4},
                "stagnation": {"window": 100}},
        solution={"x": "x"})
    # fluent loop builder == the shipped JACOBI_LOOP up to its name
    raw = b.to_spec()
    ref = dict(specs.JACOBI_LOOP, name="jac")
    assert lowering.spec_digest(raw) == lowering.spec_digest(ref)

    n = 48
    k = jax.random.PRNGKey(0)
    m = jax.random.normal(k, (n, n), jnp.float32)
    A = m @ m.T / n + jnp.eye(n)
    A = A + 2.0 * jnp.diag(jnp.sum(jnp.abs(A), axis=1))
    rhs = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    from repro.solvers.iterative import jacobi_dinv
    res = blas.compile(b).run(A=A, b=rhs, x0=jnp.zeros_like(rhs),
                              dinv=jacobi_dinv(A),
                              omega=jnp.float32(1.0))
    assert bool(res.converged)


def _fluent_gmres(m):
    """specs.gmres_loop(m) rebuilt through the loop-handle tier."""
    m1 = m + 1
    b = blas.program("gmres", dtype="float32")
    b.operand("A", "matrix").operand("b", "vector")
    b.operand("x0", "vector")
    b.setup(specs.NRM2, inputs={"x": "b"}, outputs={"norm": "bnorm"})
    b.setup(specs.RESIDUAL, inputs={"x": "x0"},
            outputs={"r": "r0", "rnorm": "rnorm0"})
    x = b.state("x", init="x0")
    b.state("r", init="r0")
    b.state("rn", init="rnorm0", kind="scalar")
    b.feedback(x="x_next", r="r_next", rn="rnorm")

    arnoldi = b.inner_loop(
        counter="j",
        state={"V": {"kind": "stack", "slots": m1, "of": "vector",
                     "init": {"slot0": "v0"}},
               "Hc": {"kind": "stack", "slots": m, "of": "vector",
                      "len": m1},
               "gs": {"kind": "stack", "slots": m1, "of": "scalar",
                      "init": {"slot0": "rn"}}},
        body=[
            blas.read("vj", "V", "j"),
            blas.stage(specs.GMRES_MATVEC, inputs={"v": "vj"}),
            blas.stage(specs.GMRES_PROJ, inputs={"g": "gs"}),
            blas.stage(specs.GMRES_ORTH),
            blas.let(inv_hn="1 / hnorm"),
            blas.stage(specs.GMRES_SCAL,
                       inputs={"alpha": "inv_hn", "x": "w2"},
                       outputs={"out": "vnext"}),
            blas.store("V", "j + 1", "vnext"),
            blas.store("Hc", "j", "h"),
            blas.store("Hc", "j", "hnorm", at="j + 1"),
        ],
        count=m,
        yields={"Vb": "V", "Hcb": "Hc", "g0": "gs"})

    givens = b.inner_loop(
        counter="t",
        state={"R": {"kind": "stack", "slots": m1, "of": "vector",
                     "init": {"from": "Hm"}},
               "g": {"kind": "stack", "slots": m1, "of": "scalar",
                     "init": {"from": "g0"}}},
        body=[
            blas.read("rj", "R", "t"),
            blas.read("rj1", "R", "t + 1"),
            blas.read("hjj", "rj", "t"),
            blas.read("hsub", "rj1", "t"),
            blas.let(den="sqrt(hjj * hjj + hsub * hsub)",
                     c="hjj / den", s="hsub / den"),
            blas.stage(specs.GMRES_ROT),
            blas.store("R", "t", "rja"),
            blas.store("R", "t + 1", "rj1a"),
            blas.read("gj", "g", "t"),
            blas.let(gjn="c * gj", gj1n="-s * gj"),
            blas.store("g", "t", "gjn"),
            blas.store("g", "t + 1", "gj1n"),
        ],
        count=m,
        yields={"Rf": "R", "gf": "g"})

    backsub = b.inner_loop(
        counter="i",
        state={"y": {"kind": "stack", "slots": m, "of": "scalar"},
               "xa": {"init": "x"}},
        body=[
            blas.let(q=f"{m - 1} - i"),
            blas.read("Rq", "Rf", "q"),
            blas.read("gq", "gf", "q"),
            blas.stage(specs.GMRES_DOT,
                       inputs={"row": "Rq", "yv": "y"}),
            blas.read("rqq", "Rq", "q"),
            blas.let(yq="(gq - acc) / rqq"),
            blas.store("y", "q", "yq"),
            blas.read("vq", "Vb", "q"),
            blas.stage(specs.GMRES_AXPY,
                       inputs={"yq": "yq", "v": "vq", "x": "xa"},
                       outputs={"xn": "xn"}),
        ],
        count=m,
        feedback={"xa": "xn"},
        yields={"x_next": "xa"})

    b.iterate(
        body=[
            blas.let(inv_beta="1 / rn"),
            blas.stage(specs.GMRES_SCAL,
                       inputs={"alpha": "inv_beta", "x": "r"},
                       outputs={"out": "v0"}),
            arnoldi,
            blas.stage(specs.GMRES_TRANSPOSE, inputs={"Hb": "Hcb"}),
            givens,
            backsub,
            blas.stage(specs.RESIDUAL, inputs={"x": "x_next"},
                       outputs={"r": "r_next", "rnorm": "rnorm"}),
        ],
        stop={"metric": "rnorm", "init": "rnorm0", "scale": "bnorm",
              "rtol": 1e-6, "max_iters": 50},
        guards={"nonfinite": ["x_next"],
                "divergence": {"factor": 1e4},
                "stagnation": {"window": 10}},
        solution={"x": x})          # a StateRef as the solution source
    return b


def test_fluent_gmres_digest_matches_shipped_spec():
    """The loop-handle tier reaches the whole v2 grammar: the fluent
    construction is digest-identical to specs.gmres_loop(m)."""
    b = _fluent_gmres(8)
    assert lowering.spec_digest(b.to_spec()) == \
        lowering.spec_digest(specs.gmres_loop(m=8))


def test_fluent_bicgstab_cond_digest_matches_shipped_spec():
    b = blas.program("bicgstab", dtype="float32")
    b.operand("A", "matrix").operand("b", "vector")
    b.operand("x0", "vector")
    b.setup(specs.NRM2, inputs={"x": "b"}, outputs={"norm": "bnorm"})
    b.setup(specs.RESIDUAL, inputs={"x": "x0"},
            outputs={"r": "r0", "rnorm": "rnorm0"})
    b.state("x", init="x0")
    b.state("r", init="r0")
    b.state("rhat", init="r0")
    b.state("p", init="r0")
    b.state("rho", init="rnorm0 * rnorm0", kind="scalar")
    b.feedback(x="x_next", r="r_next", p="p_next", rho="rho_next")
    b.iterate(
        body=[
            blas.stage(specs.BICG_MATVEC1),
            blas.let(alpha="rho / rv", neg_alpha="-alpha"),
            blas.stage(specs.BICG_SUPDATE),
            b.cond(
                "snorm <= threshold",
                then=[
                    blas.stage(specs.BICG_XHALF,
                               outputs={"x_half": "x_next"}),
                    blas.let(r_next="s", p_next="p", rho_next="rho",
                             rnorm="snorm"),
                ],
                orelse=[
                    blas.stage(specs.BICG_MATVEC2),
                    blas.let(omega="ts / tt", neg_omega="-omega"),
                    blas.stage(specs.BICG_XRUPDATE),
                    blas.let(beta="(rho_next / rho) * (alpha / omega)"),
                    blas.stage(specs.BICG_PUPDATE,
                               inputs={"r": "r_next"}),
                ]),
        ],
        stop={"metric": "rnorm", "init": "rnorm0", "scale": "bnorm",
              "rtol": 1e-6, "max_iters": 200},
        guards={"nonfinite": ["x_next"],
                "breakdown": [{"value": "rv", "below": 1e-30}],
                "divergence": {"factor": 1e4},
                "stagnation": {"window": 50}},
        solution={"x": "x"})
    assert lowering.spec_digest(b.to_spec()) == \
        lowering.spec_digest(specs.BICGSTAB_LOOP)


def test_fluent_gmres_compiles_and_solves():
    import jax
    b = _fluent_gmres(6)
    exe = blas.compile(b)
    n = 32
    k = jax.random.PRNGKey(5)
    A = jax.random.normal(k, (n, n), jnp.float32) / jnp.sqrt(n) \
        + 3.0 * jnp.eye(n)
    rhs = jax.random.normal(jax.random.PRNGKey(6), (n,), jnp.float32)
    res = exe.run(A=A, b=rhs, x0=jnp.zeros(n), tol=1e-6)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, rhs),
                               rtol=1e-3, atol=1e-4)


def test_state_and_feedback_handles_misuse():
    b = blas.program("p")
    b.state("x", init="x0")
    with pytest.raises(blas.BuilderError, match="duplicate state"):
        b.state("x", init="x0")
    with pytest.raises(blas.BuilderError, match="slot0=.*not init="):
        b.state("V", init="x0", slots=4, of="vector")
    with pytest.raises(blas.BuilderError, match="slot0=.*conflict"):
        b.state("V", slots=4, of="vector", slot0="a", from_="buf")
    with pytest.raises(blas.BuilderError, match="needs init="):
        b.state("y")
    b.feedback(x="x_next")
    with pytest.raises(blas.BuilderError,
                       match="b.state.*AND passed"):
        b.iterate(state={"x": "x0"}, body=[blas.let(a="1")],
                  stop={"metric": "a", "max_iters": 1})
    # a dataflow builder rejects the loop handles
    b2 = blas.program("df")
    b2.axpy(alpha=1.0, x="x", y="y")
    with pytest.raises(blas.BuilderError, match="dataflow builder"):
        b2.state("x", init="x0")
    with pytest.raises(blas.BuilderError, match="dataflow builder"):
        b2.feedback(x="x_next")


def test_inner_loop_needs_exactly_one_stop_form():
    with pytest.raises(blas.BuilderError, match="exactly one of"):
        blas.inner_loop(state={"h": "a"}, body=[blas.let(z="h")])
    with pytest.raises(blas.BuilderError, match="exactly one of"):
        blas.inner_loop(state={"h": "a"}, body=[blas.let(z="h")],
                        count=3,
                        stop={"metric": "z", "max_iters": 3})


def test_state_ref_coerces_in_read_store_and_yields():
    v = blas.StateRef("V")
    assert blas.read("vj", v, "j")["read"]["from"] == "V"
    assert blas.store(v, "j", "w")["store"]["into"] == "V"
    st = blas.inner_loop(state={"V": {"kind": "stack", "slots": 2,
                                      "of": "scalar"}},
                         body=[blas.let(z="1")], count=2,
                         yields={"out": blas.StateRef("V")})
    assert st["iterate"]["yield"]["out"] == "V"


def test_let_preserves_binding_order():
    st = blas.let(rz_next="rnorm * rnorm", beta="rz_next / rz")
    assert list(st["let"]) == ["rz_next", "beta"]


def test_builder_digest_matches_lowering_digest():
    b = blas.ProgramBuilder.from_spec(specs.RESIDUAL)
    assert b.digest() == lowering.spec_digest(specs.RESIDUAL)
    # the lowering layer accepts the builder itself (to_spec protocol)
    assert lowering.spec_digest(b) == b.digest()
    ir = lowering.compile_cached(b)
    assert ir is lowering.compile_cached(specs.RESIDUAL)


# ---------------------------------------------------------------------------
# Builder misuse: error messages
# ---------------------------------------------------------------------------


def test_unknown_routine_is_attribute_error_naming_registry():
    b = blas.program("p")
    with pytest.raises(AttributeError, match="frobnicate"):
        b.frobnicate(x="x")
    with pytest.raises(blas.BuilderError, match="unknown BLAS routine"):
        b.add("frobnicate", x="x")


def test_unknown_port_names_the_valid_ones():
    b = blas.program("p")
    with pytest.raises(blas.BuilderError, match=r"no port or scalar 'w'"):
        b.dot(w="u")
    with pytest.raises(blas.BuilderError, match=r"inputs: \['x', 'y'\]"):
        b.dot(w="u")


def test_duplicate_routine_name_rejected_at_call_time():
    b = blas.program("p")
    b.axpy(name="up", alpha=1.0, x="x", y="y")
    with pytest.raises(blas.BuilderError, match="duplicate routine name"):
        b.axpy(name="up", alpha=1.0, x="x", y="y")


def test_dangling_port_from_other_builder_rejected():
    b1 = blas.program("p1")
    z = b1.axpy(alpha=1.0, x="x", y="y")
    b2 = blas.program("p2")
    with pytest.raises(blas.BuilderError, match="different builder"):
        b2.dot(x=z, y="u")


def test_scalar_cannot_take_a_port():
    b = blas.program("p")
    d = b.dot(x="x", y="y")
    with pytest.raises(blas.BuilderError, match="scalar stream"):
        b.axpy(alpha=d, x="x", y="y")


def test_out_alias_on_multi_output_needs_a_dict():
    b = blas.program("p")
    with pytest.raises(blas.BuilderError, match="single-output"):
        b.rot(c=1.0, s=0.0, x="x", y="y", out="rotated")


def test_mixing_dataflow_and_loop_construction_rejected():
    b = blas.program("p")
    b.axpy(alpha=1.0, x="x", y="y")
    with pytest.raises(blas.BuilderError, match="dataflow builder"):
        b.operand("A", "matrix")
    b2 = blas.program("q")
    b2.operand("A", "matrix")
    with pytest.raises(blas.BuilderError, match="loop builder"):
        b2.axpy(alpha=1.0, x="x", y="y")


def test_loop_builder_without_iterate_fails_to_serialize():
    b = blas.program("q")
    b.operand("A", "matrix")
    with pytest.raises(blas.BuilderError, match="no iterate"):
        b.to_spec()


def test_failed_add_leaves_builder_unchanged():
    b = blas.program("p")
    z = b.axpy(alpha=1.0, x="v", y="w")
    before = b.to_spec()
    with pytest.raises(blas.BuilderError):
        b.dot(x=z, y="u", out={"bogus": "beta"})
    assert b.to_spec() == before       # no dangling connection
    b.dot(x=z, y="u", out="beta")      # retry now succeeds...
    exe = blas.compile(b)              # ...and compiles cleanly
    assert exe.output_names == ["beta"]


def test_roundtrip_preserves_unknown_toplevel_keys():
    raw = {"name": "annotated", "comment": "kept verbatim",
           "routines": [{"blas": "dot", "name": "d0"}]}
    rt = blas.ProgramBuilder.from_spec(raw).to_spec()
    assert rt["comment"] == "kept verbatim"
    assert lowering.spec_digest(rt) == lowering.spec_digest(raw)


def test_loop_builder_rejects_dataflow_knobs_early():
    b = blas.program("loopy", window_size=512)
    with pytest.raises(blas.BuilderError, match="window_size"):
        b.operand("A", "matrix")


def test_build_validates_through_the_spec_layer():
    b = blas.program("p")
    b.axpy(alpha=1.0, x="x", y="y")
    spec = b.build()
    assert isinstance(spec, spec_mod.ProgramSpec)
    empty = blas.program("nothing")
    with pytest.raises(spec_mod.SpecError, match="no routines"):
        empty.build()
